"""AdamW — pure-pytree implementation (no optax), f32 moments.

Moments are sharded with the ZeRO-1 rule (distributed/sharding.py): the
param's TP spec plus 'data' on the largest replicated dim, so optimizer
memory scales down with the full mesh, not just the model axis. Because
the update is elementwise, GSPMD re-shards grads into the moment sharding
(reduce-scatter on the data axis) and the updated params back (all-gather)
— exactly the ZeRO-1 communication pattern, derived from the sharding
annotations instead of hand-written collectives.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState, lr):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    count = state.count + 1
    c1 = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / c1
        vhat = v / c2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(new_m, new_v, count), metrics
