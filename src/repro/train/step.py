"""Train-step builder: loss + grad + AdamW, microbatching, pjit shardings.

`make_train_step(model, mesh, ...)` returns (step_fn, state_shardings,
batch_shardings) ready for jax.jit(in_shardings=..., out_shardings=...).
The step is a pure function (TrainState, batch) -> (TrainState, metrics);
fault tolerance lives a level up (train/loop.py checkpoints TrainState).

Microbatching (grad accumulation) uses a lax.scan over microbatch slices —
the activation-memory lever for the 480B-class cells.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    batch_shardings,
    opt_state_shardings,
    params_shardings,
)
from repro.train.optim import AdamWConfig, OptState, adamw_init, adamw_update
from repro.train.schedule import cosine_schedule


class TrainState(NamedTuple):
    params: Any
    opt: OptState
    step: jax.Array


@dataclass(frozen=True)
class TrainHParams:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1
    adamw: AdamWConfig = AdamWConfig()


def init_train_state(model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))


def make_train_step(model, hp: TrainHParams):
    """Returns step_fn(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def step_fn(state: TrainState, batch):
        n_micro = hp.microbatches
        if n_micro > 1:
            def micro_slice(i, leaf):
                mb = leaf.shape[0] // n_micro
                return jax.lax.dynamic_slice_in_dim(leaf, i * mb, mb, 0)

            def body(gsum, i):
                mb = jax.tree_util.tree_map(
                    lambda l: micro_slice(i, l), batch)
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state.params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return gsum, {**metrics, "loss": loss}

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            gsum, ms = jax.lax.scan(body, gzero, jnp.arange(n_micro))
            grads = jax.tree_util.tree_map(lambda g: g / n_micro, gsum)
            metrics = jax.tree_util.tree_map(
                lambda a: jnp.mean(a, axis=0), ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state.params, batch)
            metrics = {**metrics, "loss": loss}

        lr = cosine_schedule(state.step, peak_lr=hp.peak_lr,
                             warmup_steps=hp.warmup_steps,
                             total_steps=hp.total_steps)
        params, opt, opt_metrics = adamw_update(
            hp.adamw, state.params, grads, state.opt, lr)
        metrics.update(opt_metrics)
        return TrainState(params, opt, state.step + 1), metrics

    return step_fn


# ------------------------------------------------------------- shardings
def train_state_shardings(state_shapes: TrainState, cfg, mesh):
    psh = params_shardings(state_shapes.params, cfg, mesh)
    replicated = NamedSharding(mesh, P())
    return TrainState(
        params=psh,
        opt=OptState(
            mu=opt_state_shardings(state_shapes.opt.mu, cfg, mesh),
            nu=opt_state_shardings(state_shapes.opt.nu, cfg, mesh),
            count=replicated,
        ),
        step=replicated,
    )


def train_batch_shardings(batch_specs, mesh):
    return batch_shardings(batch_specs, mesh)
