"""Sharded, manifest-versioned, async checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json       — leaf paths, shapes, dtypes, step, config
            shard_<host>.npz    — this host's leaf fragments (here: host 0)
            COMMIT              — written last; restore ignores uncommitted
                                  directories (crash-consistent)

Fault-tolerance contract (DESIGN.md §8):
  * save() never blocks the train loop: the TrainState is device_get'd and
    handed to a writer thread (async checkpointing).
  * restore() onto a *different* mesh is supported: arrays are saved
    unsharded-logical (host gathers its fragments; single-process here),
    and re-sharded by the caller's shardings on load — that is the elastic
    restart path (checkpoint from 512 chips, resume on 256).
  * retention: keep the last `keep` committed checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.utils.pytree import tree_map_with_path_str


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}

    def visit(path, leaf):
        out[path] = np.asarray(leaf)
        return leaf

    tree_map_with_path_str(visit, tree)
    return out


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------- save
    def save(self, step: int, state: Any, *, blocking: bool = False,
             extra: dict | None = None):
        """Async by default; the device->host copy happens synchronously
        (cheap relative to the write), the file I/O in a thread."""
        host_state = jax.tree_util.tree_map(np.asarray, state)
        if self._thread is not None:
            self._thread.join()          # one outstanding write at a time

        def write():
            self._write(step, host_state, extra or {})

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def _write(self, step: int, host_state, extra: dict):
        path = os.path.join(self.dir, f"step_{step:08d}")
        tmp = path + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        flat = _flatten_with_paths(host_state)
        manifest = {
            "step": step,
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in flat.items()},
            **extra,
        }
        np.savez(os.path.join(tmp, "shard_0.npz"),
                 **{k.replace("/", "|"): v for k, v in flat.items()})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "COMMIT"), "w") as f:
            f.write("ok")
        if os.path.exists(path):
            shutil.rmtree(path)
        os.rename(tmp, path)
        self._gc()

    def _gc(self):
        steps = self.committed_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -------------------------------------------------------- restore
    def committed_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.dir):
            p = os.path.join(self.dir, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(p, "COMMIT"))):
                steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.committed_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, *, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Restore into the structure of `like`. If `shardings` is given
        (possibly for a different mesh than the save — elastic restart),
        leaves are device_put with those shardings."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, "shard_0.npz"))
        flat = {k.replace("|", "/"): data[k] for k in data.files}

        shard_flat = (_flatten_with_paths_structs(shardings)
                      if shardings is not None else {})

        def rebuild(p, leaf):
            arr = flat[p]
            if leaf is not None and hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            sh = shard_flat.get(p)
            if sh is not None:
                return jax.device_put(arr, sh)
            return jax.numpy.asarray(arr)

        restored = tree_map_with_path_str(rebuild, like)
        return restored, step


def _flatten_with_paths_structs(tree) -> dict[str, Any]:
    out: dict[str, Any] = {}

    def visit(path, leaf):
        out[path] = leaf
        return leaf

    tree_map_with_path_str(visit, tree)
    return out
