"""Fault-tolerant training loop.

Responsibilities (DESIGN.md §8):
  * periodic async checkpointing + crash-consistent resume (restart picks
    up from the last committed step; the data pipeline is step-indexed so
    no data state needs saving),
  * straggler/anomaly watchdog: per-step wall-time EWMA; steps slower than
    `straggler_factor`× the EWMA are logged (on real pods this feeds the
    scheduler's host-exclusion — here it exercises the code path),
  * elastic restart hook: on `ElasticRescale` the loop re-lowers the step
    for the new mesh and restores state with the new shardings (exercised
    by tests/test_elastic.py on CPU sub-meshes),
  * metrics CSV logging.
"""
from __future__ import annotations

import csv
import os
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.train.checkpoint import CheckpointManager
from repro.train.data import Prefetcher, SyntheticLMStream


class ElasticRescale(Exception):
    """Raised by the environment when device topology changed."""


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: str = "checkpoints"
    metrics_csv: Optional[str] = None
    straggler_factor: float = 3.0


@dataclass
class LoopReport:
    steps_run: int
    final_metrics: dict
    straggler_steps: list = field(default_factory=list)
    resumed_from: Optional[int] = None


def train_loop(step_fn: Callable, state, stream: SyntheticLMStream,
               cfg: LoopConfig, *, state_shardings=None,
               put_batch: Callable | None = None) -> tuple[Any, LoopReport]:
    """Runs step_fn until total_steps, checkpointing and resuming."""
    ckpt = CheckpointManager(cfg.ckpt_dir)
    resumed_from = None
    latest = ckpt.latest_step()
    if latest is not None:
        state, _ = ckpt.restore(state, step=latest,
                                shardings=state_shardings)
        resumed_from = latest

    start_step = int(np.asarray(jax.device_get(state.step)))
    prefetch = Prefetcher(stream, start_step=start_step)
    writer = None
    if cfg.metrics_csv:
        os.makedirs(os.path.dirname(cfg.metrics_csv) or ".", exist_ok=True)
        writer = open(cfg.metrics_csv, "a", newline="")
        csv_out = csv.writer(writer)

    ewma = None
    stragglers: list[int] = []
    metrics = {}
    try:
        step = start_step
        while step < cfg.total_steps:
            _, batch = prefetch.next()
            if put_batch is not None:
                batch = put_batch(batch)
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            # straggler watchdog. The first measured step includes jit
            # compilation — seeding the EWMA with it masks real stragglers
            # for dozens of steps (found by test_straggler_watchdog_*):
            # seed from the second step instead.
            if step == start_step:
                pass
            elif ewma is None:
                ewma = dt
            else:
                if dt > cfg.straggler_factor * ewma:
                    stragglers.append(step)
                ewma = 0.9 * ewma + 0.1 * dt
            step += 1
            if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
                ckpt.save(step, state)
            if writer and step % cfg.log_every == 0:
                m = {k: float(np.asarray(jax.device_get(v)))
                     for k, v in metrics.items()}
                csv_out.writerow([step, m.get("loss"), m.get("grad_norm"),
                                  m.get("lr"), dt])
                writer.flush()
    finally:
        prefetch.close()
        ckpt.wait()
        if writer:
            writer.close()

    final = {k: float(np.asarray(jax.device_get(v)))
             for k, v in metrics.items()} if metrics else {}
    return state, LoopReport(steps_run=step - start_step,
                             final_metrics=final,
                             straggler_steps=stragglers,
                             resumed_from=resumed_from)
