from repro.train.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.schedule import cosine_schedule
from repro.train.step import make_train_step, TrainState

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "cosine_schedule",
    "make_train_step",
    "TrainState",
]
