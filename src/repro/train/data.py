"""Deterministic synthetic LM data pipeline with host sharding + prefetch.

Production shape: each host materializes only its shard of the global batch
(host_id / n_hosts), derived from a counter-based PRNG so any host can
reproduce any step's data after a restart (checkpoint stores only the step
counter — data state is free). A background thread prefetches batches.

The synthetic stream is Zipf-distributed token ids with a deterministic
"repeated n-gram" structure so the LM loss actually decreases — enough
signal for the end-to-end example runs required by deliverable (b).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram: int = 8          # repeated-structure period (learnable signal)


class SyntheticLMStream:
    """Deterministic, shardable synthetic token stream."""

    def __init__(self, cfg: DataConfig, *, host_id: int = 0,
                 n_hosts: int = 1):
        assert cfg.global_batch % n_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.local_batch = cfg.global_batch // n_hosts

    def batch_at(self, step: int) -> dict:
        """Materialize this host's shard of the batch for `step`."""
        cfg = self.cfg
        rs = np.random.RandomState(
            (cfg.seed * 1_000_003 + step * 131 + self.host_id) % (2**31))
        b, t = self.local_batch, cfg.seq_len
        # Zipf base stream, clipped to vocab
        base = rs.zipf(cfg.zipf_a, size=(b, t)).astype(np.int64)
        base = np.minimum(base, cfg.vocab - 1)
        # inject learnable periodic structure: every ngram-th token repeats
        # the token ngram positions earlier
        if cfg.ngram > 1 and t > cfg.ngram:
            base[:, cfg.ngram:] = np.where(
                (np.arange(cfg.ngram, t) % cfg.ngram) == 0,
                base[:, :-cfg.ngram], base[:, cfg.ngram:])
        tokens = base.astype(np.int32)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((b, 1), -100, np.int32)], axis=1)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch queue over a step-indexed stream."""

    def __init__(self, stream: SyntheticLMStream, *, start_step: int = 0,
                 depth: int = 2):
        self.stream = stream
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
