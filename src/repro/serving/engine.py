"""Continuous-batching serving engine — the paper's protocol applied to LLM
inference (DESIGN.md §4).

Mapping onto the paper's constructs:

  task      — one unit of request work: a prefill chunk or one decode step
  recipe    — (request id, kind, chunk index); created when the request's
              previous task completes (bottom-up, asynchronous arrival)
  record    — "which requests already have a task ahead of me in this
              window" — the conflict rule is simply `same request id`
              (each request's tasks read/write only its own slot state =
              localized dynamics; different requests commute)
  chain     — the engine's pending-task window, rebuilt every iteration
              from per-request progress + the arrival queue
  wave      — the set of commuting front tasks, executed as ONE batched
              decode step (plus prefill chunk calls); exactly the paper's
              "different workers may handle different agents at different
              times", realized SPMD

Straggler mitigation: long prompts are split into `prefill_chunk` tasks, so
a 32k-prompt request never blocks the decode wave of other requests —
adaptive handling of heterogeneous work, the paper's headline property.

The engine is scheduler-faithful rather than throughput-tuned on CPU: the
wavefront schedule it produces is asserted (tests) to give bit-identical
tokens to per-request sequential decoding.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.records import prefix_conflicts, wave_levels
from repro.obs.profiler import annotate
from repro.obs.stats import finalize_stats
from repro.obs.trace import current_tracer


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # [T] int32
    max_new_tokens: int
    eos_token: Optional[int] = None
    out_tokens: list = field(default_factory=list)
    slot: Optional[int] = None
    prefill_done: int = 0               # prompt tokens already prefilled
    done: bool = False


class _SlotConflicts:
    """Recipe/record adapter for the scheduler: same-request tasks conflict
    (serial chain per request); distinct requests commute."""

    @staticmethod
    def conflicts(a, b, *, strict: bool = True):
        return a["rid"] == b["rid"]


class ServingEngine:
    def __init__(self, model, params, *, n_slots: int, max_len: int,
                 prefill_chunk: int = 64, greedy: bool = True):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.greedy = greedy

        self.states = model.init_states(n_slots, max_len)
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}      # slot -> request
        self.free_slots = list(range(n_slots))
        self.finished: list[Request] = []
        self.iterations = 0
        self.wave_sizes: list[int] = []
        self.prefill_tasks = 0
        self.decode_tasks = 0

        def _decode_step(params, last, states):
            with annotate("protocol.decode_wave"):
                return model.decode_step(params, last, states)

        self._decode = jax.jit(_decode_step)
        self._prefill_chunk_fns: dict[int, object] = {}

    # ------------------------------------------------------------ admit
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        while self.queue and self.free_slots:
            req = self.queue.pop(0)
            req.slot = self.free_slots.pop(0)
            # reset the slot's streaming state (previous occupant's KV ring
            # / SSM state / position counter must not leak)
            self._scatter_state(
                self.model.init_states(1, self.max_len), req.slot)
            self.active[req.slot] = req

    # -------------------------------------------------------- scheduling
    def _build_window(self):
        """One pending task per active request (its chain head), in request
        arrival order — the engine's view of the paper's chain."""
        recipes = []
        for slot, req in sorted(self.active.items(), key=lambda kv: kv[1].rid):
            if req.done:
                continue
            if req.prefill_done < len(req.prompt):
                recipes.append({"rid": req.rid, "kind": 0, "slot": slot})
            elif len(req.out_tokens) < req.max_new_tokens:
                recipes.append({"rid": req.rid, "kind": 1, "slot": slot})
        return recipes

    def _schedule_wave(self, recipes):
        """Run the paper's scheduler over the window; return wave-0 tasks.
        With one task per request the wave is the whole window — the
        machinery matters when chains interleave (tests exercise windows
        with multiple tasks per request)."""
        if not recipes:
            return []
        w = len(recipes)
        arr = {
            "rid": jnp.asarray([r["rid"] for r in recipes], jnp.int32),
        }
        valid = jnp.ones((w,), bool)
        conf = prefix_conflicts(_SlotConflicts.conflicts, arr, valid)
        levels = np.asarray(wave_levels(conf, valid))
        return [r for r, l in zip(recipes, levels) if l == 0]

    # -------------------------------------------------------- execution
    def _scatter_state(self, slot_states, slot: int):
        """Write a single-slot state pytree into the batched states."""

        def merge(path, big, small):
            if path == "pos" or path.endswith("enc_out"):
                return big.at[slot].set(small[0])
            # seg leaves: [Lseg, B, ...]
            return big.at[:, slot].set(small[:, 0])

        from repro.utils.pytree import tree_map_with_path_str

        flat_big, tdef = jax.tree_util.tree_flatten(self.states)
        # paths must match between big and small: map with path over big,
        # pulling the corresponding small leaf positionally
        small_leaves = jax.tree_util.tree_leaves(slot_states)
        paths = []

        def collect(path, leaf):
            paths.append(path)
            return leaf

        tree_map_with_path_str(collect, self.states)
        merged = [merge(p, b, s)
                  for p, b, s in zip(paths, flat_big, small_leaves)]
        self.states = jax.tree_util.tree_unflatten(tdef, merged)

    def _gather_state(self, slot: int):
        def take(path, big):
            if path == "pos" or path.endswith("enc_out"):
                return big[slot:slot + 1]
            return big[:, slot:slot + 1]

        from repro.utils.pytree import tree_map_with_path_str

        return tree_map_with_path_str(take, self.states)

    def _exec_prefill(self, task):
        req = self.active[task["slot"]]
        first = req.prefill_done == 0
        chunk = req.prompt[req.prefill_done:
                           req.prefill_done + self.prefill_chunk]
        t = len(chunk)
        slot_states = self._gather_state(task["slot"])
        batch = {"tokens": jnp.asarray(chunk, jnp.int32)[None]}
        key = (t, first)
        if key not in self._prefill_chunk_fns:
            import functools

            prefill = functools.partial(
                self.model.prefill, chunked=True, include_prefix=first)

            def _prefill_chunk(params, batch, states, _fn=prefill):
                with annotate("protocol.prefill_chunk"):
                    return _fn(params, batch, states)

            self._prefill_chunk_fns[key] = jax.jit(_prefill_chunk)
        logits, slot_states = self._prefill_chunk_fns[key](
            self.params, batch, slot_states)
        self._scatter_state(slot_states, task["slot"])
        req.prefill_done += t
        if req.prefill_done >= len(req.prompt):
            # prompt complete: the prefill's last logits seed decoding
            tok = int(np.asarray(jnp.argmax(logits[0])))
            self._append_token(req, tok)

    def _exec_decode_wave(self, tasks):
        slots = [t["slot"] for t in tasks]
        last = np.zeros((self.n_slots, 1), np.int32)
        for s in slots:
            last[s, 0] = self.active[s].out_tokens[-1]
        logits, new_states = self._decode(
            self.params, jnp.asarray(last), self.states)
        # commit only wave slots (masked merge = conflict-free wave write)
        mask = np.zeros((self.n_slots,), bool)
        for s in slots:
            mask[s] = True
        mask_j = jnp.asarray(mask)

        def merge(path, old, new):
            if path == "pos" or path.endswith("enc_out"):
                m = mask_j.reshape((-1,) + (1,) * (old.ndim - 1))
                return jnp.where(m, new, old)
            m = mask_j.reshape((1, -1) + (1,) * (old.ndim - 2))
            return jnp.where(m, new, old)

        from repro.utils.pytree import tree_map_with_path_str

        flat_old, tdef = jax.tree_util.tree_flatten(self.states)
        new_leaves = jax.tree_util.tree_leaves(new_states)
        paths = []

        def collect(path, leaf):
            paths.append(path)
            return leaf

        tree_map_with_path_str(collect, self.states)
        self.states = jax.tree_util.tree_unflatten(
            tdef, [merge(p, o, n)
                   for p, o, n in zip(paths, flat_old, new_leaves)])

        toks = np.asarray(jnp.argmax(logits, axis=-1))
        for s in slots:
            self._append_token(self.active[s], int(toks[s]))

    def _append_token(self, req: Request, tok: int):
        req.out_tokens.append(tok)
        if ((req.eos_token is not None and tok == req.eos_token)
                or len(req.out_tokens) >= req.max_new_tokens):
            req.done = True
            self.finished.append(req)
            self.free_slots.append(req.slot)
            del self.active[req.slot]

    # ------------------------------------------------------------- run
    def step(self) -> bool:
        """One protocol iteration. Returns False when fully idle.

        With a span tracer installed (``repro.obs.tracing()``) each
        iteration emits a fenced ``schedule`` span (admit + window build
        + wave-0 selection) and an ``execute`` span (prefill chunks +
        the batched decode wave) — the same taxonomy the batch engines
        use, so serving traces render through ``report.py trace``. The
        untraced path is guarded by one ``current_tracer()`` check."""
        tr = current_tracer()
        if tr is None:
            self._admit()
            wave = self._schedule_wave(self._build_window())
        else:
            with tr.span("schedule", index=self.iterations):
                self._admit()
                wave = self._schedule_wave(self._build_window())
        if not wave:
            return bool(self.queue or self.active)
        self.wave_sizes.append(len(wave))
        prefills = [t for t in wave if t["kind"] == 0]
        decodes = [t for t in wave if t["kind"] == 1]
        if tr is None:
            self._exec_wave(prefills, decodes)
        else:
            with tr.span("execute", index=self.iterations,
                         prefills=len(prefills), decodes=len(decodes)) as sp:
                self._exec_wave(prefills, decodes)
                jax.block_until_ready(self.states)
                sp.args["wave"] = len(prefills) + len(decodes)
        self.prefill_tasks += len(prefills)
        self.decode_tasks += len(decodes)
        self.iterations += 1
        return True

    def _exec_wave(self, prefills, decodes):
        for t in prefills:
            self._exec_prefill(t)
        if decodes:
            self._exec_decode_wave(decodes)

    def run(self, max_iterations: int = 100_000):
        tr = current_tracer()
        if tr is None:
            it = 0
            while self.step():
                it += 1
                if it > max_iterations:
                    raise RuntimeError("engine did not converge")
            return self.finished
        with tr.span("run", engine="serving", window=self.n_slots,
                     total_tasks=0) as sp:
            it = 0
            while self.step():
                it += 1
                if it > max_iterations:
                    raise RuntimeError("engine did not converge")
            jax.block_until_ready(self.states)
            sp.args["total_tasks"] = self.prefill_tasks + self.decode_tasks
        return self.finished

    def run_stats(self) -> dict:
        """Engine-run statistics through the same typed registry boundary
        as every batch engine (``repro.obs.stats.finalize_stats``): the
        core keys map one iteration -> one window with one executed wave,
        plus the serving-group task/request counters."""
        waves = self.wave_sizes
        total = self.prefill_tasks + self.decode_tasks
        return finalize_stats({
            "total_tasks": total,
            "n_windows": self.iterations,
            "total_waves": len(waves),
            "mean_parallelism": total / max(len(waves), 1),
            "serving_prefill_tasks": self.prefill_tasks,
            "serving_decode_tasks": self.decode_tasks,
            "serving_requests_finished": len(self.finished),
        })
