"""Wave-at-a-time window execution — the SPMD core of the protocol.

Given a window of recipes and their wave levels, executes the window one
wave at a time; each wave is a single vectorized (vmap-style,
shard_map-able) masked batch. Semantics: identical to sequential chain
execution (tested by property tests), because waves are executed in
topological order and tasks within a wave commute.

The streaming runners that used to live here (``WavefrontRunner``,
``run_sequential``) moved behind the execution-engine registry in
``repro.engine`` — which also adds the multi-device ``sharded`` engine;
this module keeps the per-window primitive they share plus
backwards-compatible re-exports.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.records import wave_levels, window_conflicts
from repro.obs.profiler import annotate


def execute_window(model, state, recipes, valid, *, strict: bool = True,
                   levels: jax.Array | None = None):
    """Execute one window of tasks by waves. Returns (state, n_waves).

    Scheduling (the conflict matrix and the wave levels) routes through
    the model's footprint protocol when available — conflict and levels
    Pallas kernels on TPU, fused jnp fallbacks on CPU — and through the
    legacy broadcast predicate otherwise. Pass precomputed ``levels`` to
    split scheduling from execution (the engines' window pipeline does).
    """
    if levels is None:
        conf = window_conflicts(model, recipes, valid, strict=strict)
        levels = wave_levels(conf, valid)
    n_waves = jnp.max(levels) + 1  # dynamic

    def cond(carry):
        w, _ = carry
        return w < n_waves

    def body(carry):
        w, st = carry
        mask = levels == w
        with annotate("protocol.wave"):
            st = model.execute_wave(st, recipes, mask)
        return w + 1, st

    with annotate("protocol.execute_window"):
        _, state = jax.lax.while_loop(cond, body, (jnp.int32(0), state))
    return state, n_waves


def window_schedule_stats(model, recipes, valid, *, strict: bool = True):
    """Host-side scheduling statistics for a window (used by benchmarks):
    wave count, wave sizes, parallelism profile."""
    conf = window_conflicts(model, recipes, valid, strict=strict)
    levels = wave_levels(conf, valid)
    import numpy as np

    lv = np.asarray(levels)
    lv = lv[lv >= 0]
    n_waves = int(lv.max()) + 1 if lv.size else 0
    sizes = np.bincount(lv, minlength=n_waves) if n_waves else np.array([])
    return {
        "n_tasks": int(lv.size),
        "n_waves": n_waves,
        "wave_sizes": sizes,
        "mean_parallelism": float(lv.size / max(n_waves, 1)),
        "conflict_density": float(np.asarray(conf).sum())
        / max(1, lv.size * (lv.size - 1) / 2),
    }


def __getattr__(name):  # PEP 562 — lazy to avoid a core <-> engine cycle
    if name == "WavefrontRunner":
        from repro.engine.wavefront import WavefrontRunner

        return WavefrontRunner
    if name == "run_sequential":
        from repro.engine.sequential import run_sequential

        return run_sequential
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
