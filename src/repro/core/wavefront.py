"""Wavefront execution engine — the SPMD realization of the paper's protocol.

Given a window of recipes and their wave levels, executes the window one wave
at a time; each wave is a single vectorized (vmap-style, shard_map-able)
masked batch. Semantics: identical to sequential chain execution (tested by
property tests), because waves are executed in topological order and tasks
within a wave commute.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.records import wave_levels, window_conflicts


def execute_window(model, state, recipes, valid, *, strict: bool = True,
                   levels: jax.Array | None = None):
    """Execute one window of tasks by waves. Returns (state, n_waves).

    Scheduling (the conflict matrix) routes through the model's footprint
    protocol when available — Pallas kernel on TPU, fused jnp fallback on
    CPU — and through the legacy broadcast predicate otherwise.
    """
    if levels is None:
        conf = window_conflicts(model, recipes, valid, strict=strict)
        levels = wave_levels(conf, valid)
    n_waves = jnp.max(levels) + 1  # dynamic

    def cond(carry):
        w, _ = carry
        return w < n_waves

    def body(carry):
        w, st = carry
        mask = levels == w
        st = model.execute_wave(st, recipes, mask)
        return w + 1, st

    _, state = jax.lax.while_loop(cond, body, (jnp.int32(0), state))
    return state, n_waves


def window_schedule_stats(model, recipes, valid, *, strict: bool = True):
    """Host-side scheduling statistics for a window (used by benchmarks):
    wave count, wave sizes, parallelism profile."""
    conf = window_conflicts(model, recipes, valid, strict=strict)
    levels = wave_levels(conf, valid)
    import numpy as np

    lv = np.asarray(levels)
    lv = lv[lv >= 0]
    n_waves = int(lv.max()) + 1 if lv.size else 0
    sizes = np.bincount(lv, minlength=n_waves) if n_waves else np.array([])
    return {
        "n_tasks": int(lv.size),
        "n_waves": n_waves,
        "wave_sizes": sizes,
        "mean_parallelism": float(lv.size / max(n_waves, 1)),
        "conflict_density": float(np.asarray(conf).sum())
        / max(1, lv.size * (lv.size - 1) / 2),
    }


class WavefrontRunner:
    """Streaming engine: create a window (<= the paper's C·n creation
    quantum), schedule it, execute by waves, repeat. The window boundary is
    a conservative barrier, so cross-window ordering is trivially preserved.
    """

    def __init__(self, model, *, window: int = 256, strict: bool = True,
                 jit: bool = True):
        self.model = model
        self.window = int(window)
        self.strict = strict

        def _step(state, base_key, start_index):
            recipes = model.create_tasks(base_key, start_index, self.window)
            valid = jnp.ones((self.window,), dtype=bool)
            state, n_waves = execute_window(model, state, recipes, valid,
                                            strict=self.strict)
            return state, n_waves

        def _step_partial(state, base_key, start_index, count):
            recipes = model.create_tasks(base_key, start_index, self.window)
            valid = jnp.arange(self.window) < count
            state, n_waves = execute_window(model, state, recipes, valid,
                                            strict=self.strict)
            return state, n_waves

        self._step = jax.jit(_step) if jit else _step
        self._step_partial = (
            jax.jit(_step_partial) if jit else _step_partial
        )

    def run(self, state: Any, total_tasks: int, *, seed: int = 0):
        """Run total_tasks tasks; returns (state, stats)."""
        base_key = jax.random.key(seed)
        t = 0
        total_waves = 0
        n_windows = 0
        while t < total_tasks:
            k = min(self.window, total_tasks - t)
            if k == self.window:
                state, n_waves = self._step(state, base_key, t)
            else:
                state, n_waves = self._step_partial(state, base_key, t, k)
            total_waves += int(n_waves)
            n_windows += 1
            t += k
        stats = {
            "total_tasks": total_tasks,
            "n_windows": n_windows,
            "total_waves": total_waves,
            "mean_parallelism": total_tasks / max(total_waves, 1),
        }
        return state, stats


def run_sequential(model, state, total_tasks: int, *, seed: int = 0,
                   window: int = 256):
    """Oracle runner: same task stream, strictly sequential execution."""
    base_key = jax.random.key(seed)
    t = 0
    seq = jax.jit(
        lambda st, key, start, count: model.execute_sequential(
            st, model.create_tasks(key, start, window), count
        )
    )
    while t < total_tasks:
        k = min(window, total_tasks - t)
        state = seq(state, base_key, t, k)
        t += k
    return state
