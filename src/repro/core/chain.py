"""The task chain — a bidirectional linked list, as in the paper (§3.3).

Used by the discrete-event protocol simulator (core/workersim.py). The SPMD
wavefront engine uses windowed recipe arrays instead (core/wavefront.py);
this structure exists to model the *protocol itself* faithfully, including
cheap interior erasure, the enter-lock and the erase-lock.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional


@dataclass
class TaskNode:
    index: int                      # global chain index (creation order)
    recipe: Any                     # model-side creation payload
    prev: Optional["TaskNode"] = field(default=None, repr=False)
    next: Optional["TaskNode"] = field(default=None, repr=False)
    executing_by: Optional[int] = None   # worker id currently executing
    occupant: Optional[int] = None       # worker id stationed here (per-task lock)
    erased: bool = False


class TaskChain:
    """Bidirectional linked list of pending tasks with O(1) erase."""

    def __init__(self) -> None:
        self.head: Optional[TaskNode] = None
        self.tail: Optional[TaskNode] = None
        self.n_pending = 0
        self.n_created = 0

    def append(self, recipe: Any) -> TaskNode:
        node = TaskNode(index=self.n_created, recipe=recipe)
        self.n_created += 1
        self.n_pending += 1
        if self.tail is None:
            self.head = self.tail = node
        else:
            node.prev = self.tail
            self.tail.next = node
            self.tail = node
        return node

    def erase(self, node: TaskNode) -> None:
        assert not node.erased
        node.erased = True
        self.n_pending -= 1
        p, n = node.prev, node.next
        if p is not None:
            p.next = n
        else:
            self.head = n
        if n is not None:
            n.prev = p
        else:
            self.tail = p

    def __len__(self) -> int:
        return self.n_pending

    def __iter__(self):
        node = self.head
        while node is not None:
            nxt = node.next
            yield node
            node = nxt
