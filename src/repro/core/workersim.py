"""Discrete-event simulator of the paper's worker–chain protocol (§3.3).

This is the *protocol-faithful* reproduction path: n workers, per-task locks
(a worker cannot move onto a stationed, non-executing worker — hand-over-hand
locking), the enter-lock (serialized creation, incl. the empty-chain case),
the erase-lock (serialized erasure), cycles, and the C tasks-created-per-cycle
limit. Costs are supplied by a model adapter and calibrated against measured
per-task execution times (benchmarks/), which is how we reproduce Fig. 2 /
Fig. 3 on a single-core container where real threads cannot exhibit speedup.

Event granularity: one event per worker move/decision plus one completion
event per execution — the honest level at which occupancy ("is some worker
stationed there *now*?") and execution state must be evaluated. Executing
tasks remain on the chain until their completion event, so later workers
correctly integrate their recipes (precedence is never violated).

The simulator never executes model math; it replays the schedule the
protocol would produce and integrates its makespan. Model semantics are
validated separately by the wavefront engine's sequential-equivalence
property tests.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Any, Callable, Optional

from repro.core.chain import TaskChain, TaskNode


@dataclass
class DESCosts:
    """Protocol overhead constants (seconds) — calibrated in benchmarks."""

    visit: float = 2e-7     # record integration + pointer move (one list hop)
    create: float = 5e-7    # creation bookkeeping (excl. model creation work)
    erase: float = 2e-7     # erase under erase-lock
    enter: float = 2e-7     # return to chain start / enter chain


@dataclass
class DESModel:
    """Host-side adapter for a MABS model.

    recipes_fn(i)        -> recipe payload for global task index i
    exec_cost_fn(recipe) -> execution-part cost in seconds
    create_cost_fn()     -> model-side creation cost in seconds
    record_new()         -> empty record
    record_add(rec, recipe) -> record with recipe folded in (may mutate)
    depends(rec, recipe) -> True if task-at-hand depends on the record
    """

    recipes_fn: Callable[[int], Any]
    exec_cost_fn: Callable[[Any], float]
    create_cost_fn: Callable[[], float]
    record_new: Callable[[], Any]
    record_add: Callable[[Any, Any], Any]
    depends: Callable[[Any, Any], bool]


@dataclass
class _Worker:
    wid: int
    node: Optional[TaskNode] = None      # current station (None = outside chain)
    record: Any = None
    created_this_cycle: int = 0
    executed: int = 0
    visited: int = 0
    blocked_on: Optional[TaskNode] = None


@dataclass
class DESResult:
    makespan: float
    executed_per_worker: list[int]
    visits_per_worker: list[int]
    n_tasks: int
    events: int
    max_chain_len: int


class ProtocolSimulator:
    """Event-driven simulation of the worker–chain workflow."""

    def __init__(self, model: DESModel, *, n_workers: int, total_tasks: int,
                 tasks_per_cycle: int = 6, costs: DESCosts | None = None):
        self.model = model
        self.n = n_workers
        self.total = total_tasks
        self.C = tasks_per_cycle
        self.costs = costs or DESCosts()

    # ------------------------------------------------------------------
    def run(self) -> DESResult:
        model, costs = self.model, self.costs
        chain = TaskChain()
        workers = [_Worker(wid=i) for i in range(self.n)]
        seq = itertools.count()           # FIFO tie-break
        q: list[tuple[float, int, int, str]] = []
        creation_busy_until = 0.0         # enter-lock: one creation at a time
        erase_busy_until = 0.0            # erase-lock: one erase at a time
        executed = 0
        events = 0
        max_chain = 0
        waiters: dict[int, list[int]] = {}  # task index -> blocked worker ids
        done_time = 0.0

        def push(t: float, wid: int, kind: str = "decide") -> None:
            heapq.heappush(q, (t, next(seq), wid, kind))

        def wake_waiters(node: TaskNode, t: float) -> None:
            for wid in waiters.pop(node.index, []):
                workers[wid].blocked_on = None
                push(t, wid)

        for w in workers:
            push(0.0, w.wid)

        while q:
            t, _, wid, kind = heapq.heappop(q)
            events += 1
            w = workers[wid]
            max_chain = max(max_chain, len(chain))

            # ---------------- completion of an execution ----------------
            if kind == "finish":
                node = w.node
                assert node is not None and node.executing_by == wid
                t_erase_done = max(t, erase_busy_until) + costs.erase
                erase_busy_until = t_erase_done
                chain.erase(node)
                node.executing_by = None
                node.occupant = None
                executed += 1
                w.executed += 1
                w.node = None
                wake_waiters(node, t_erase_done)
                done_time = max(done_time, t_erase_done)
                push(t_erase_done + costs.enter, wid)
                continue

            if w.blocked_on is not None:
                continue  # stale event; this worker is parked until woken

            # ---------------- (re-)entering the chain -------------------
            if w.node is None:
                w.record = model.record_new()
                w.created_this_cycle = 0
                target = chain.head
                if target is None:
                    if chain.n_created < self.total:
                        # create under the enter-lock
                        t_start = max(t, creation_busy_until)
                        dt = costs.create + model.create_cost_fn()
                        creation_busy_until = t_start + dt
                        node = chain.append(model.recipes_fn(chain.n_created))
                        node.occupant = wid
                        w.node = node
                        push(t_start + dt, wid)
                    elif executed >= self.total:
                        done_time = max(done_time, t)  # retire
                    else:
                        # everything created; stragglers still executing.
                        # Wait for the next completion instead of spinning.
                        push(t + 50 * costs.enter, wid)
                    continue
                node = target
            else:
                node = w.node

            # a worker "in transit" may arrive at a task that was executed
            # and erased meanwhile — follow next pointers to the first
            # live task (erased nodes keep their next pointer)
            while node is not None and node.erased:
                node = node.next
            if node is None:
                # overshot the tail: create or end the cycle
                w.node = None
                if chain.n_created < self.total \
                        and w.created_this_cycle < self.C:
                    t_start = max(t, creation_busy_until)
                    dt = costs.create + model.create_cost_fn()
                    creation_busy_until = t_start + dt
                    new_node = chain.append(model.recipes_fn(chain.n_created))
                    new_node.occupant = wid
                    w.node = new_node
                    w.created_this_cycle += 1
                    push(t_start + dt, wid)
                else:
                    push(t + costs.enter, wid)
                continue
            w.node = node

            # ------------- per-task lock: can we stand here? -------------
            if (node.occupant is not None and node.occupant != wid
                    and node.executing_by is None):
                w.blocked_on = node
                waiters.setdefault(node.index, []).append(wid)
                continue
            if node.occupant is None:
                node.occupant = wid
            w.node = node

            # --------------------- decision ------------------------------
            busy = node.executing_by is not None and node.executing_by != wid
            dependent = busy or model.depends(w.record, node.recipe)

            if not dependent:
                # EXECUTE (task stays on chain until "finish"). Workers
                # blocked behind this station may now pass (paper: a located
                # worker may be passed once it is executing).
                node.executing_by = wid
                wake_waiters(node, t)
                push(t + model.exec_cost_fn(node.recipe), wid, "finish")
                continue

            # SKIP: integrate recipe, hand-over-hand move to next
            w.record = model.record_add(w.record, node.recipe)
            w.visited += 1
            if node.occupant == wid:
                node.occupant = None
                wake_waiters(node, t + costs.visit)
            nxt = node.next
            if nxt is not None:
                w.node = nxt
                push(t + costs.visit, wid)
                continue

            # ----------------- at the chain tail: create -----------------
            if chain.n_created < self.total and w.created_this_cycle < self.C:
                t_start = max(t + costs.visit, creation_busy_until)
                dt = costs.create + model.create_cost_fn()
                creation_busy_until = t_start + dt
                new_node = chain.append(model.recipes_fn(chain.n_created))
                new_node.occupant = wid
                w.node = new_node
                w.created_this_cycle += 1
                push(t_start + dt, wid)
            else:
                # cycle ends: leave the chain, return to start
                w.node = None
                push(t + costs.visit + costs.enter, wid)

        if executed < self.total:
            raise RuntimeError(
                f"protocol deadlock: executed {executed}/{self.total}")

        return DESResult(
            makespan=done_time,
            executed_per_worker=[w.executed for w in workers],
            visits_per_worker=[w.visited for w in workers],
            n_tasks=executed,
            events=events,
            max_chain_len=max_chain,
        )
