"""Core of the reproduction: the paper's adaptive-parallelization protocol.

  model.py      — recipe/record model interface (paper §3.5)
  records.py    — vectorized worker records: prefix-conflict matrices
  wavefront.py  — per-window wave execution primitive (SPMD adaptation)
  chain.py      — bidirectional task chain (paper §3.3)
  workersim.py  — paper-faithful n-worker discrete-event simulator
  protocol.py   — high-level API

Streaming execution lives behind the engine registry (``repro.engine``):
sequential oracle, single-device wavefront, and the multi-device sharded
engine share the primitives here.
"""
from repro.core.model import MABSModel, footprint_conflicts
from repro.core.protocol import (
    ProtocolConfig,
    run_engine,
    run_oracle,
    run_wavefront,
    simulate_protocol,
)
from repro.core.records import (
    critical_path_length,
    prefix_conflicts,
    wave_levels,
    wave_levels_capped,
    window_conflicts,
)
from repro.core.wavefront import execute_window
from repro.core.workersim import DESCosts, DESModel, DESResult, ProtocolSimulator
from repro.engine.sequential import run_sequential
from repro.engine.wavefront import WavefrontRunner

__all__ = [
    "run_engine",
    "MABSModel",
    "footprint_conflicts",
    "window_conflicts",
    "ProtocolConfig",
    "run_oracle",
    "run_wavefront",
    "simulate_protocol",
    "prefix_conflicts",
    "wave_levels",
    "wave_levels_capped",
    "critical_path_length",
    "WavefrontRunner",
    "execute_window",
    "run_sequential",
    "DESCosts",
    "DESModel",
    "DESResult",
    "ProtocolSimulator",
]
