"""Model interface for the adaptive-parallelization protocol.

Mirrors the paper's two model-side concepts:

  * ``recipe``  — the information a task holds after its *creation* part
                  (paper §3.5). Here: a pytree of arrays with a leading
                  window dimension W (structure-of-arrays).
  * ``record``  — the worker-side dependence test (paper §3.5). Here: a
                  vectorized pairwise ``conflicts`` predicate from which the
                  prefix-conflict matrix is built (core/records.py).

Creation/execution depth split (paper §3.4): ``create_tasks`` performs the
creation part (including drawing any randomness, bound to the task's global
chain index — see utils/prng.py) and returns recipes; ``execute_wave``
performs the execution part for a whole *wave* of commuting tasks at once.

Two conflict rules are exposed:

  * ``strict=True``  (default) — full dependence closure: flow (RAW) +
    anti (WAR) + output (WAW) hazards. Guarantees bit-exact equivalence
    with sequential execution (property-tested).
  * ``strict=False`` — the rule exactly as stated in the paper: the record
    accumulates the *write* sets of skipped tasks and tests the task at
    hand's *read* set against them (flow hazards). It omits
    anti-dependences (see DESIGN.md §10: for Axelrod the paper's record
    rule misses ``tgt_i == src_j``) and standalone output hazards.
    Provided for fidelity experiments; tests demonstrate the divergence.

Footprint protocol: instead of hand-writing the pairwise ``conflicts``
predicate, a model may declare per-task id footprints via
``task_footprint(recipes) -> (read_ids [W, nr], write_ids [W, nw])``
(int32, -1 = unused slot). The default ``conflicts`` is then derived from
footprint intersection (``footprint_conflicts``), and — more importantly —
the wavefront engine routes footprint models through the tiled Pallas
prefix-conflict kernel (kernels/conflict) instead of materializing the
broadcast predicate: one dependence implementation shared by the
scheduler, the DES adapter, and the kernel.
"""
from __future__ import annotations

import abc
from typing import Any

import jax
import jax.numpy as jnp

Recipes = Any  # pytree of arrays with leading dim W
State = Any  # pytree of arrays
Footprint = Any  # (read_ids, write_ids) int32 arrays, -1 padded


def footprint_conflicts(fp_a: Footprint, fp_b: Footprint, *,
                        strict: bool = True) -> jax.Array:
    """Pairwise conflict predicate derived from id footprints.

    fp_a/fp_b are (read_ids, write_ids) with broadcastable leading dims and
    trailing id dims; negative ids are unused slots. Later task a conflicts
    with earlier task b iff W_b ∩ R_a (flow; the paper's record rule), plus
    W_b ∩ W_a (output) and W_a ∩ R_b (anti) under the strict closure.
    """
    reads_a, writes_a = fp_a
    reads_b, writes_b = fp_b

    def any_match(x, y):
        eq = x[..., :, None] == y[..., None, :]
        used = (x[..., :, None] >= 0) & (y[..., None, :] >= 0)
        return jnp.any(eq & used, axis=(-1, -2))

    c = any_match(reads_a, writes_b)
    if strict:
        c = c | any_match(writes_a, writes_b) | any_match(writes_a, reads_b)
    return c


class MABSModel(abc.ABC):
    """A multi-agent simulation expressible as a chain of localized tasks."""

    #: name used in benchmarks / registries
    name: str = "mabs"

    @abc.abstractmethod
    def init_state(self, rng: jax.Array) -> State:
        """Initial simulation state (does not count toward measured T)."""

    @abc.abstractmethod
    def create_tasks(self, base_key: jax.Array, start_index: int, count: int) -> Recipes:
        """Creation part for tasks [start_index, start_index+count).

        Must be a pure function of (base_key, global task index) so that
        scheduling cannot influence the realized randomness.
        """

    def task_footprint(self, recipes: Recipes) -> Footprint | None:
        """Optional id footprints: (read_ids [W, nr], write_ids [W, nw]),
        int32 with -1 marking unused slots. Returning footprints (instead
        of None) gives the model the derived ``conflicts`` below and puts
        window scheduling on the Pallas/jnp conflict-kernel path. The
        leading dims follow the recipe leaves' (so broadcasting recipes
        broadcasts footprints)."""
        return None

    def task_write_agents(self, recipes: Recipes) -> jax.Array | None:
        """Optional [W, nt] int32 *state-row* indices each task writes
        (-1 = unused slot). This is the sharded engine's ownership
        contract: a task executes on every device whose agent-row block
        contains at least one of its write targets. Distinct from
        ``task_footprint``, whose ids may live in abstract spaces (e.g.
        SIRS block ids over two buffers); return None (the default) when
        write targets are not state rows — the sharded engine then runs
        every task on every device (redundant compute, identical result).
        """
        return None

    def task_read_agents(self, recipes: Recipes) -> jax.Array | None:
        """Optional [W, nr] int32 *state-row* indices each task reads
        (-1 = unused slot) — the read-side companion of
        ``task_write_agents`` and the sharded engine's halo-exchange
        contract: with both hooks declared, each wave gathers only the
        window's read ∪ write rows (O(max_degree · window) values)
        instead of all-gathering the full O(N) agent state.

        The contract: the rows returned must cover every state row whose
        *pre-wave* value can influence the task's writes, across all
        state leaves — including rows the task only partially overwrites
        (e.g. Axelrod writes one feature of the target's trait row, so
        ``tgt`` must be listed). Like ``task_write_agents`` — and unlike
        ``task_footprint`` — these are actual state-row indices, shared
        by every leaf. Return None (the default) to keep the sharded
        engine on its replicated all-gather fallback.
        """
        return None

    def conflicts(self, a: Recipes, b: Recipes, *, strict: bool = True) -> jax.Array:
        """Pairwise predicate: does later task ``a`` conflict with earlier
        task ``b``? Broadcasts: a has shape [...,1]-style leading dims vs b.
        Used by records.prefix_conflicts to build the W×W matrix.

        Default: derived from ``task_footprint`` intersection. Models
        without footprints must override.
        """
        fa, fb = self.task_footprint(a), self.task_footprint(b)
        if fa is None or fb is None:
            raise NotImplementedError(
                f"{type(self).__name__} must implement task_footprint() "
                "or override conflicts()")
        return footprint_conflicts(fa, fb, strict=strict)

    @abc.abstractmethod
    def execute_wave(self, state: State, recipes: Recipes, mask: jax.Array) -> State:
        """Execution part for all tasks where mask[i]; must be correct for
        any conflict-free subset (the scheduler guarantees the mask is one).
        """

    def execute_sequential(self, state: State, recipes: Recipes, count: int) -> State:
        """Oracle: execute tasks one by one in chain order. Default
        implementation runs execute_wave with one-hot masks; models may
        override with a faster scan."""
        import jax.numpy as jnp

        n = jax.tree_util.tree_leaves(recipes)[0].shape[0]

        def body(i, st):
            mask = (jnp.arange(n) == i) & (i < count)
            return self.execute_wave(st, recipes, mask)

        return jax.lax.fori_loop(0, count, body, state)

    # ---- cost model hooks for the discrete-event protocol simulator ----

    def task_cost(self, recipes: Recipes, index: int) -> float:
        """Predicted execution cost (seconds) of one task — calibrated by
        benchmarks; used by core/workersim.py. Default: uniform unit cost."""
        return 1.0

    def creation_cost(self) -> float:
        """Predicted cost of the creation part of one task."""
        return 0.05
