"""High-level protocol API — binds a MABS model to an execution engine.

Engines are pluggable (``repro.engine``): ``sequential`` (the oracle),
``wavefront`` (single-device vectorized waves), ``sharded`` (waves
sharded over the agent axis of a device mesh; its schedules split the
halo rows derived from the models' ``task_read_agents`` /
``task_write_agents`` contracts *per wave*, so wave w's communication is
O(rows wave w touches) instead of the whole window's halo — let alone
the full O(N) state), ``sharded_window_halo`` (the monolithic
window-halo rung) and ``sharded_replicated`` (the all_gather layout,
the fallback for models without the row contracts), plus the
paper-faithful discrete-event simulator. All array engines run the
identical task stream; under the strict hazard rule they are bit-exact
vs each other.

The paper's "choices in applying the protocol" (§3.4) map to:
  chain granularity  -> the model's task definition (e.g. agents per subset)
  task depth         -> what create_tasks precomputes (ids + PRNG binding)
  workflow params    -> n_workers, C (DES); window size + engine choice +
                        cross-window overlap (wavefront/sharded engines;
                        ``halo=...``, ``overlap=...`` and ``devices=...``
                        pass through run_engine kwargs)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.core.workersim import DESCosts, DESModel, ProtocolSimulator


@dataclass
class ProtocolConfig:
    window: int = 256          # recipe-window size (windowed engines)
    n_workers: int = 4         # n  (DES engine)
    tasks_per_cycle: int = 6   # C  (DES engine; paper keeps C=6)
    strict: bool = True        # full hazard closure vs paper's record rule
    engine: str = "wavefront"  # registry name (repro.engine)
    #: cross-window overlap knob: True fuses window k+1's independent head
    #: waves into window k's tail drain (record carry-over, engine docs);
    #: False forces the conservative window barrier; None (default) keeps
    #: each engine's own default (the ``*_overlap`` registry entries
    #: default on, everything else defaults to the barrier fallback).
    overlap: bool | None = None


def run_engine(model, state, total_tasks: int, *, seed: int = 0,
               config: ProtocolConfig | None = None,
               engine: str | None = None, **engine_kwargs):
    """Run total_tasks through the engine named by ``engine`` (or
    ``config.engine``); extra kwargs go to the engine constructor (e.g.
    ``devices=...`` for the sharded engine, ``overlap=...`` to flip the
    cross-window overlap knob — default from config). Returns
    (state, stats)."""
    import inspect

    from repro.engine import get_engine, make_engine

    cfg = config or ProtocolConfig()
    name = engine or cfg.engine
    if cfg.overlap is not None and "overlap" not in engine_kwargs:
        # inject only into constructors that take the knob: custom
        # engines registered with the pre-overlap signature keep working
        # for every cfg.overlap value (False asks for the barrier
        # behavior such an engine already has)
        params = inspect.signature(get_engine(name).__init__).parameters
        if "overlap" in params or any(
                p.kind is inspect.Parameter.VAR_KEYWORD
                for p in params.values()):
            engine_kwargs["overlap"] = cfg.overlap
    eng = make_engine(name, model, window=cfg.window,
                      strict=cfg.strict, **engine_kwargs)
    return eng.run(state, total_tasks, seed=seed)


def run_wavefront(model, state, total_tasks: int, *, seed: int = 0,
                  config: ProtocolConfig | None = None):
    return run_engine(model, state, total_tasks, seed=seed,
                      config=config, engine="wavefront")


def run_oracle(model, state, total_tasks: int, *, seed: int = 0,
               config: ProtocolConfig | None = None):
    from repro.engine.sequential import run_sequential

    cfg = config or ProtocolConfig()
    return run_sequential(model, state, total_tasks, seed=seed,
                          window=cfg.window)


def simulate_protocol(des_model: DESModel, total_tasks: int, *,
                      config: ProtocolConfig | None = None,
                      costs: DESCosts | None = None):
    cfg = config or ProtocolConfig()
    sim = ProtocolSimulator(
        des_model,
        n_workers=cfg.n_workers,
        total_tasks=total_tasks,
        tasks_per_cycle=cfg.tasks_per_cycle,
        costs=costs,
    )
    return sim.run()
