"""High-level protocol API — binds a MABS model to an execution engine.

Three engines over the same model:

  * ``run_wavefront``  — SPMD wavefront engine (production path; TPU target).
  * ``run_sequential`` — chain-order oracle (correctness reference).
  * ``simulate_protocol`` — paper-faithful discrete-event simulation of the
    n-worker shared-memory workflow (reproduces the paper's T(s, n) figures).

The paper's "choices in applying the protocol" (§3.4) map to:
  chain granularity  -> the model's task definition (e.g. agents per subset)
  task depth         -> what create_tasks precomputes (ids + PRNG binding)
  workflow params    -> n_workers, C (DES); window size (wavefront engine)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

from repro.core.wavefront import WavefrontRunner, run_sequential
from repro.core.workersim import DESCosts, DESModel, ProtocolSimulator


@dataclass
class ProtocolConfig:
    window: int = 256          # recipe-window size (wavefront engine)
    n_workers: int = 4         # n  (DES engine)
    tasks_per_cycle: int = 6   # C  (DES engine; paper keeps C=6)
    strict: bool = True        # full hazard closure vs paper's record rule


def run_wavefront(model, state, total_tasks: int, *, seed: int = 0,
                  config: ProtocolConfig | None = None):
    cfg = config or ProtocolConfig()
    runner = WavefrontRunner(model, window=cfg.window, strict=cfg.strict)
    return runner.run(state, total_tasks, seed=seed)


def run_oracle(model, state, total_tasks: int, *, seed: int = 0,
               config: ProtocolConfig | None = None):
    cfg = config or ProtocolConfig()
    return run_sequential(model, state, total_tasks, seed=seed,
                          window=cfg.window)


def simulate_protocol(des_model: DESModel, total_tasks: int, *,
                      config: ProtocolConfig | None = None,
                      costs: DESCosts | None = None):
    cfg = config or ProtocolConfig()
    sim = ProtocolSimulator(
        des_model,
        n_workers=cfg.n_workers,
        total_tasks=total_tasks,
        tasks_per_cycle=cfg.tasks_per_cycle,
        costs=costs,
    )
    return sim.run()
