"""Worker-record machinery, vectorized.

In the paper, each worker carries a *record*: an accumulator over the recipes
of the tasks it has skipped, answering "does the task at hand depend on any
task I have passed?". On SPMD hardware the equivalent object is the
*prefix-conflict matrix* over a window of W tasks:

    C[i, j] = 1  iff  j < i  and  task_i conflicts with task_j

Row i of C is exactly the record a worker would have accumulated after
skipping tasks j<i — materialized for all workers/positions at once. The
matrix is the protocol's O(W²) overhead term; the Pallas kernel in
kernels/conflict implements the id-matching variant with 128×128 tiling.

The same record algebra extends across the window boundary: the
rectangular block ``cross_window_conflicts`` is the check a worker's
record would perform against the *previous* window's undrained tail,
``carry_frontier`` reduces it to a per-task release level, and
``wave_levels(base=...)`` schedules the next window under that floor —
the machinery behind the engines' cross-window overlap (record
carry-over, docs/engine.md).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def prefix_conflicts(
    conflict_fn: Callable,
    recipes,
    valid: jax.Array,
    *,
    strict: bool = True,
) -> jax.Array:
    """Build the strictly-lower-triangular conflict matrix.

    conflict_fn(a, b, strict=...) is the model's pairwise predicate
    (later a vs earlier b). recipes is a pytree with leading dim W;
    valid is a [W] bool mask for padded windows.
    Returns C [W, W] bool with C[i, j] == later-task-i-conflicts-with-j,
    zero outside j < i or where either task is invalid.
    """
    from repro.obs.profiler import annotate

    w = valid.shape[0]

    with annotate("protocol.conflict_predicate"):
        # Broadcast: rows = later task i, cols = earlier task j.
        rows = jax.tree_util.tree_map(lambda x: x[:, None], recipes)
        cols = jax.tree_util.tree_map(lambda x: x[None, :], recipes)
        conf = conflict_fn(rows, cols, strict=strict)  # [W, W] broadcast

        lower = jnp.tril(jnp.ones((w, w), dtype=bool), k=-1)
        return conf & lower & valid[:, None] & valid[None, :]


def window_conflicts(model, recipes, valid: jax.Array, *,
                     strict: bool = True,
                     backend: str | None = None) -> jax.Array:
    """Model-agnostic conflict matrix for one window.

    Footprint models (task_footprint != None) route through the conflict
    kernel — tiled Pallas on TPU, a fused jnp elementwise pass elsewhere
    (kernels/conflict/ops.py). Predicate-only models fall back to the
    broadcast ``prefix_conflicts`` path. Both produce the identical
    strictly-lower-triangular [W, W] bool matrix.
    """
    fp = model.task_footprint(recipes)
    if fp is not None:
        from repro.kernels.conflict.ops import conflict_matrix

        read_ids, write_ids = fp
        return conflict_matrix(read_ids, write_ids, valid, strict=strict,
                               backend=backend)
    return prefix_conflicts(model.conflicts, recipes, valid, strict=strict)


def cross_window_conflicts(model, recipes_prev, valid_prev,
                           recipes_next, valid_next, *,
                           strict: bool = True,
                           backend: str | None = None) -> jax.Array:
    """Cross-window conflict block [W_next, W_prev] (bool).

    Row i = task i of the *later* window (k+1), column j = task j of the
    *earlier* window (k): C[i, j] == 1 iff next-task-i conflicts with
    prev-task-j. In chain order every prev task precedes every next task,
    so the block is a full rectangle — no triangular mask, only validity.
    ``valid_prev`` doubles as the *alive* mask of window k's not-yet-
    drained tail: columns of already-executed tasks are masked out (they
    impose no ordering constraint on the next window).

    Footprint models route through the rectangular-tile conflict kernel
    (Pallas on TPU, fused jnp elsewhere — kernels/conflict/ops.py);
    predicate-only models fall back to the broadcast pairwise predicate.
    This is the record carry-over of the overlapped engines: the check a
    worker's record would perform against tasks of the previous window.
    """
    fp_next = model.task_footprint(recipes_next)
    if fp_next is not None:
        from repro.kernels.conflict.ops import conflict_block

        reads_n, writes_n = fp_next
        reads_p, writes_p = model.task_footprint(recipes_prev)
        return conflict_block(reads_n, writes_n, reads_p, writes_p,
                              valid_next, valid_prev, strict=strict,
                              backend=backend)
    rows = jax.tree_util.tree_map(lambda x: x[:, None], recipes_next)
    cols = jax.tree_util.tree_map(lambda x: x[None, :], recipes_prev)
    conf = model.conflicts(rows, cols, strict=strict)
    return conf & valid_next[:, None] & valid_prev[None, :]


def carry_frontier(cross: jax.Array, levels_prev: jax.Array) -> jax.Array:
    """Per-task level floor imposed by the previous window's tail.

        carry[i] = max{ levels_prev[j] + 1 : cross[i, j] }   (else 0)

    ``levels_prev`` holds the previous window's *remaining* wave levels
    on the current level clock (-1 = already drained or padded), so a
    drained task contributes ``-1 + 1 = 0`` — no constraint. The result
    is the carry-over frontier: feeding it to ``wave_levels(base=...)``
    pins every next-window task strictly after the tail waves it
    conflicts with, which is exactly the cross-window record guarantee.
    """
    from repro.obs.profiler import annotate

    with annotate("protocol.carry_frontier"):
        gated = jnp.where(cross, levels_prev[None, :] + 1, 0)
        return jnp.max(gated, axis=1, initial=0).astype(jnp.int32)


def wave_levels(conflicts: jax.Array, valid: jax.Array, *,
                base: jax.Array | None = None,
                backend: str | None = None) -> jax.Array:
    """DAG-level (wavefront) assignment.

        level[i] = max(base[i], 1 + max{ level[j] : j < i, C[i, j] })

    This is list scheduling with unbounded workers: tasks in the same level
    commute pairwise *within the window prefix semantics* — a task only
    enters level L if every earlier conflicting task sits at a level < L.
    ``base`` (optional, non-negative) is the cross-window carry frontier:
    a per-task release level below which the task may not be scheduled
    (default: no floor — the classic recurrence, level 0 for tasks with
    no earlier conflicts). Invalid (padded) slots get level -1.

    Sequential-equivalence argument: executing levels in ascending order is
    a topological order of the (strict) dependence DAG restricted to the
    window, and commuting tasks may be reordered freely (paper §3.2).

    Implementation lives in kernels/levels — the blocked Pallas kernel on
    TPU, the reference ``lax.scan`` elsewhere (backend auto-detect, like
    the conflict kernel).
    """
    from repro.kernels.levels.ops import wave_levels as _wave_levels

    return _wave_levels(conflicts, valid, base=base, backend=backend)


def wave_levels_capped(conflicts, valid, n_workers: int):
    """Finite-n list scheduling (NumPy, host-side): like wave_levels but each
    wave holds at most n_workers tasks; a task is placed in the earliest
    wave >= its dependence level that has spare capacity, scanning in chain
    order — this models n paper-workers with an ideal (zero-overhead)
    workflow and is used by the DES and the benchmarks."""
    import numpy as np

    conflicts = np.asarray(conflicts)
    valid = np.asarray(valid)
    w = conflicts.shape[0]
    levels = np.full(w, -1, dtype=np.int64)
    counts: dict[int, int] = {}
    for i in range(w):
        if not valid[i]:
            continue
        deps = np.nonzero(conflicts[i])[0]
        base = 0 if deps.size == 0 else int(levels[deps].max()) + 1
        lvl = base
        while counts.get(lvl, 0) >= n_workers:
            lvl += 1
        levels[i] = lvl
        counts[lvl] = counts.get(lvl, 0) + 1
    return levels


def critical_path_length(conflicts, valid) -> int:
    """Longest dependence chain in the window (= #waves with n=inf)."""
    lv = wave_levels(jnp.asarray(conflicts), jnp.asarray(valid))
    return int(jnp.max(lv) + 1)
