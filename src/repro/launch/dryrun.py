import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST precede every other import (including the
# __future__ import, which is why this module has none): jax locks the
# device count at first initialization.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture × input shape × mesh) cell:
  lower the real step function (train_step / prefill / decode) with
  ShapeDtypeStruct inputs and production shardings, .compile() it, and
  record memory_analysis / cost_analysis / parsed collective bytes to a
  JSON artifact consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch h2o-danube-3-4b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out artifacts/dryrun]

--all runs each cell in a fresh subprocess (XLA leaks compile-time memory
across 80 big compiles otherwise) and tolerates per-cell failures: a
failing cell records its error and the run continues.
"""
import argparse
import functools
import json
import subprocess
import sys
import time
import traceback

ARTIFACT_DIR = "artifacts/dryrun"


def _microbatch_plan(cfg, shape, mesh_devices: int, data_shards: int) -> int:
    """Pick grad-accumulation so per-device residual-stream activation
    memory (the scan carry saved for backward: L·(B/d)·T·D·2 bytes) stays
    under ~4 GiB. Powers of two, capped at the local batch — each
    microbatch slice must stay divisible by the batch sharding (measured:
    a 64-row slice over 256-way DP re-gathers activations every layer,
    +12 TiB wire on rwkv6 — see EXPERIMENTS.md §Perf iteration 2)."""
    if cfg.layout == "dp":
        data_shards = mesh_devices     # batch is sharded over every axis
    local_b = max(1, shape.global_batch // data_shards)
    bytes_act = (cfg.n_layers * local_b * shape.seq_len * cfg.d_model * 2)
    budget = 4 * 1024**3
    mb = 1
    while bytes_act / mb > budget and mb < local_b:
        mb *= 2
    return mb


# §Perf hillclimb variants (EXPERIMENTS.md): applied with --opt. Baselines
# stay untouched; optimized artifacts get the "__opt" suffix.
OPTIMIZED = {
    "h2o-danube-3-4b": dict(tp_shard_map=True),
    "deepseek-7b": dict(tp_shard_map=True),
    "rwkv6-3b": dict(layout="dp"),
    "hymba-1.5b": dict(layout="dp"),
    "smollm-360m": dict(layout="dp"),       # 0.7 GiB replicated params
    "seamless-m4t-medium": dict(layout="dp"),
    "qwen3-moe-235b-a22b": dict(moe_impl="shard_map_wg",
                                seq_shard_cache=True),
    "arctic-480b": dict(moe_impl="shard_map", seq_shard_cache=True),
    "qwen1.5-32b": dict(seq_shard_cache=True,
                        kv_cache_dtype="float8_e4m3fn"),
    "internvl2-76b": dict(seq_shard_cache=True, tp_shard_map=True),
}


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             opt: bool = False):
    import jax
    import numpy as np

    from repro.configs import SHAPES, applicable, get_config
    from repro.distributed.context import mesh_context
    from repro.distributed.sharding import (
        batch_shardings,
        params_shardings,
        states_shardings,
        data_size,
    )
    from repro.launch.hlo_analysis import analyze_collectives, \
        loop_adjusted_flops
    from repro.launch.mesh import make_production_mesh
    from repro.models.api import build_model, input_specs
    from repro.train.step import (
        TrainHParams,
        init_train_state,
        make_train_step,
        train_state_shardings,
    )

    cfg = get_config(arch)
    if opt:
        cfg = cfg.replace(**OPTIMIZED.get(arch, {}))
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + ("__opt" if opt else "")
    out_path = os.path.join(out_dir, cell_id + ".json")
    os.makedirs(out_dir, exist_ok=True)

    ok, reason = applicable(cfg, shape)
    record = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "opt": opt,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "kind": shape.kind, "status": "skipped", "skip_reason": reason,
    }
    if not ok:
        with open(out_path, "w") as f:
            json.dump(record, f, indent=1)
        print(f"[dryrun] {cell_id}: SKIP ({reason})")
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    dsize = data_size(mesh)
    model_axis = 16
    # expand KV to q-heads when Hkv doesn't divide the model axis but H
    # does — keeps attention TP-shardable (see models/attention.py)
    cfg = cfg.replace(gqa_expand=(cfg.n_heads % model_axis == 0
                                  and cfg.n_kv_heads % model_axis != 0))
    record["gqa_expand"] = cfg.gqa_expand
    model = build_model(cfg)
    key = jax.random.key(0)

    t0 = time.time()
    if shape.kind == "train":
        mb = _microbatch_plan(cfg, shape, len(jax.devices()), dsize)
        record["microbatches"] = mb
        hp = TrainHParams(microbatches=mb)
        step = make_train_step(model, hp)
        state_shapes = jax.eval_shape(
            functools.partial(init_train_state, model), key)
        state_sh = train_state_shardings(state_shapes, cfg, mesh)
        batch_specs = input_specs(cfg, shape)
        batch_sh = batch_shardings(batch_specs, mesh, layout=cfg.layout)
        with mesh_context(mesh):
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),   # params/opt updated in place
            ).lower(state_shapes, batch_specs)
    else:
        params_shapes = jax.eval_shape(model.init, key)
        psh = params_shardings(params_shapes, cfg, mesh)
        states_shapes = jax.eval_shape(
            lambda: model.init_states(shape.global_batch, shape.seq_len))
        ssh = states_shardings(states_shapes, cfg, mesh,
                               global_batch=shape.global_batch)
        batch_specs = input_specs(cfg, shape)
        if shape.kind == "prefill":
            batch_sh = batch_shardings(batch_specs, mesh, layout=cfg.layout)
            fn = functools.partial(model.prefill)
            with mesh_context(mesh):
                lowered = jax.jit(
                    fn, in_shardings=(psh, batch_sh, ssh),
                    out_shardings=(None, ssh),
                    donate_argnums=(2,),   # cache updated in place
                ).lower(params_shapes, batch_specs, states_shapes)
        else:  # decode
            tok_sh = batch_shardings(batch_specs, mesh, layout=cfg.layout)
            with mesh_context(mesh):
                lowered = jax.jit(
                    model.decode_step,
                    in_shardings=(psh, tok_sh["token"], ssh),
                    out_shardings=(None, ssh),
                    donate_argnums=(2,),   # cache updated in place
                ).lower(params_shapes, batch_specs["token"], states_shapes)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    txt = compiled.as_text()
    colls = analyze_collectives(txt)
    max_mult = loop_adjusted_flops(txt)

    record.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
        "cost_analysis": {
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
            "transcendentals": float(cost.get("transcendentals", -1.0)),
        },
        "collectives": {
            "raw_bytes": colls.raw_bytes,
            "loop_bytes": colls.loop_bytes,
            "wire_bytes": colls.wire_bytes,
            "count": colls.count,
            "unknown_trip_whiles": colls.unknown_trip_whiles,
            "total_wire_bytes": colls.total_wire(),
        },
        "max_loop_multiplier": max_mult,
        "n_devices": len(jax.devices()),
    })
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    print(f"[dryrun] {cell_id}: OK compile={t_compile:.1f}s "
          f"temp={mem.temp_size_in_bytes/2**30:.2f}GiB "
          f"args={mem.argument_size_in_bytes/2**30:.2f}GiB "
          f"wire={colls.total_wire()/2**30:.3f}GiB")
    return record


def run_all(meshes: list[str], out_dir: str, archs=None, shapes=None,
            timeout: int = 3600):
    from repro.configs import ARCHS, SHAPES

    archs = archs or list(ARCHS)
    shapes = shapes or list(SHAPES)
    results = []
    for mesh in meshes:
        for arch in archs:
            for shape in shapes:
                cell = f"{arch}__{shape}__{mesh}"
                path = os.path.join(out_dir, cell + ".json")
                if os.path.exists(path):
                    with open(path) as f:
                        rec = json.load(f)
                    if rec.get("status") in ("ok", "skipped"):
                        print(f"[dryrun] {cell}: cached")
                        results.append(rec)
                        continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh,
                       "--out", out_dir]
                try:
                    proc = subprocess.run(cmd, timeout=timeout,
                                          capture_output=True, text=True)
                    if proc.returncode != 0:
                        rec = {"arch": arch, "shape": shape, "mesh": mesh,
                               "status": "error",
                               "error": proc.stderr[-2000:]}
                        with open(path, "w") as f:
                            json.dump(rec, f, indent=1)
                        print(f"[dryrun] {cell}: ERROR")
                    else:
                        sys.stdout.write(proc.stdout)
                        with open(path) as f:
                            rec = json.load(f)
                except subprocess.TimeoutExpired:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh,
                           "status": "timeout"}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    print(f"[dryrun] {cell}: TIMEOUT")
                results.append(rec)
    n_ok = sum(r.get("status") == "ok" for r in results)
    n_skip = sum(r.get("status") == "skipped" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, "
          f"{len(results) - n_ok - n_skip} failed, of {len(results)}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf optimized variant for this arch")
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        run_all(meshes, args.out, timeout=args.timeout)
    else:
        assert args.arch and args.shape, "--arch and --shape required"
        for m in meshes:
            run_cell(args.arch, args.shape, m == "multi", args.out,
                     opt=args.opt)


if __name__ == "__main__":
    main()
