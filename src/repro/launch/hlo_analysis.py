"""Compiled-HLO analysis for the roofline report.

Extracts, from `compiled.as_text()`:

  * every collective op (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute) with its *per-device* result bytes
    (shapes in SPMD-partitioned HLO are local) and its replica-group size,
  * the `while` call graph with trip counts recovered from the loop
    condition's comparison constant (XLA materializes scan trip counts as
    `constant(N)` in the condition computation — verified on this
    toolchain), so collectives inside scanned layer bodies are multiplied
    by the real iteration count instead of being counted once
    (cost_analysis counts loop bodies ONCE — measured, see DESIGN.md §9).

Wire-cost model per op (ring algorithms, n = replica-group participants):
  all-reduce       2·(n-1)/n · bytes
  all-gather /
  reduce-scatter   (n-1)/n · bytes
  all-to-all       (n-1)/n · bytes
  collective-permute   1.0 · bytes
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_WHILE_RE = re.compile(
    r"while\(.*\), condition=([%\w\.\-]+), body=([%\w\.\-]+)")
_CALL_RE = re.compile(r"(?:call|conditional)\(.*?to_apply=([%\w\.\-]+)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w\.\-]+)\s+\(.*\)\s+->.*\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_WIRE_FACTOR = {
    "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1) / max(n, 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


@dataclass
class CollectiveStats:
    raw_bytes: dict = field(default_factory=dict)        # opcode -> bytes ×1
    loop_bytes: dict = field(default_factory=dict)       # × trip counts
    wire_bytes: dict = field(default_factory=dict)       # ring-cost adjusted
    count: dict = field(default_factory=dict)
    unknown_trip_whiles: int = 0

    def total_wire(self) -> float:
        return float(sum(self.wire_bytes.values()))

    def total_loop(self) -> float:
        return float(sum(self.loop_bytes.values()))


def parse_computations(txt: str) -> tuple[dict, str]:
    blocks: dict[str, list[str]] = {}
    entry = ""
    cur = None
    for line in txt.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            cur = m.group(1).lstrip("%")
            blocks[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if cur is not None:
            blocks[cur].append(line)
    return blocks, entry


def trip_count(cond_lines: list[str]) -> int | None:
    consts = [int(c) for l in cond_lines
              for c in re.findall(r"constant\((\d+)\)", l)]
    return max(consts) if consts else None


def analyze_collectives(txt: str) -> CollectiveStats:
    blocks, entry = parse_computations(txt)
    stats = CollectiveStats()

    def visit(name: str, mult: float, seen: tuple):
        if name not in blocks or name in seen:
            return
        lines = blocks[name]
        body = "\n".join(lines)
        # collectives directly in this computation
        for line in lines:
            m = _COLL_RE.search(line)
            if not m:
                continue
            type_str, op = m.group(1), m.group(2)
            nbytes = _shape_bytes(type_str)
            g = _GROUPS_RE.search(line)
            n_per_group = int(g.group(2)) if g else 2
            stats.raw_bytes[op] = stats.raw_bytes.get(op, 0) + nbytes
            stats.loop_bytes[op] = (stats.loop_bytes.get(op, 0)
                                    + nbytes * mult)
            stats.wire_bytes[op] = (
                stats.wire_bytes.get(op, 0)
                + nbytes * mult * _WIRE_FACTOR[op](n_per_group))
            stats.count[op] = stats.count.get(op, 0) + 1
        # recurse into whiles with trip multipliers
        for cond, wbody in _WHILE_RE.findall(body):
            cond_n, body_n = cond.lstrip("%"), wbody.lstrip("%")
            trips = trip_count(blocks.get(cond_n, []))
            if trips is None:
                trips = 1
                stats.unknown_trip_whiles += 1
            visit(body_n, mult * trips, seen + (name,))
        # plain calls / conditionals
        for callee in _CALL_RE.findall(body):
            visit(callee.lstrip("%"), mult, seen + (name,))

    visit(entry, 1.0, ())
    return stats


def loop_adjusted_flops(txt: str, flops_per_comp_hint: None = None):
    """Total trip-count product of the deepest while nest — used to sanity
    check cost_analysis undercounting (the analytic model in
    benchmarks/roofline.py is the primary FLOPs source)."""
    blocks, entry = parse_computations(txt)
    best = {"mult": 1.0}

    def visit(name, mult, seen):
        if name not in blocks or name in seen:
            return
        best["mult"] = max(best["mult"], mult)
        body = "\n".join(blocks[name])
        for cond, wbody in _WHILE_RE.findall(body):
            trips = trip_count(blocks.get(cond.lstrip("%"), [])) or 1
            visit(wbody.lstrip("%"), mult * trips, seen + (name,))

    visit(entry, 1.0, ())
    return best["mult"]
