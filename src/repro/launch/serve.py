"""Serving launcher — continuous batching via the paper's protocol.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \\
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(args.seed))

    engine = ServingEngine(model, params, n_slots=args.slots,
                           max_len=args.max_len,
                           prefill_chunk=args.prefill_chunk)
    rng = np.random.RandomState(args.seed)
    for i in range(args.requests):
        plen = int(rng.randint(4, args.max_len // 2))
        engine.submit(Request(
            rid=i,
            prompt=rng.randint(0, cfg.vocab, size=plen).astype(np.int32),
            max_new_tokens=args.max_new))

    t0 = time.perf_counter()
    finished = engine.run()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in finished)
    ws = engine.wave_sizes
    print(f"[serve] {len(finished)} requests, {total_tokens} tokens in "
          f"{dt:.2f}s ({total_tokens/dt:.1f} tok/s)")
    print(f"[serve] protocol iterations={engine.iterations}, "
          f"mean wave={np.mean(ws):.2f}, max wave={max(ws)}")
    for r in sorted(finished, key=lambda x: x.rid)[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] -> {r.out_tokens}")


if __name__ == "__main__":
    main()
