"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \\
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container use --reduced (the full configs are exercised via the
dry-run); on a real TPU slice drop --reduced and the same code path runs
the production mesh (mesh selection via --mesh).
"""
from __future__ import annotations

import argparse
import functools

import jax
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import batch_shardings
from repro.models.api import build_model
from repro.train.data import DataConfig, SyntheticLMStream
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import (
    TrainHParams,
    init_train_state,
    make_train_step,
    train_state_shardings,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--metrics-csv", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)

    hp = TrainHParams(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps,
                      microbatches=args.microbatches)
    step_fn = make_train_step(model, hp)
    state = init_train_state(model, jax.random.key(args.seed))

    state_sh = None
    put_batch = None
    if args.mesh != "none":
        from repro.launch.mesh import make_production_mesh

        mesh = make_production_mesh(multi_pod=args.mesh == "multi")
        state_shapes = jax.eval_shape(
            functools.partial(init_train_state, model),
            jax.random.key(args.seed))
        state_sh = train_state_shardings(state_shapes, cfg, mesh)
        state = jax.device_put(state, state_sh)
        step_fn = jax.jit(step_fn, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None))
    else:
        step_fn = jax.jit(step_fn)

    stream = SyntheticLMStream(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed))
    loop_cfg = LoopConfig(total_steps=args.steps,
                          ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir,
                          metrics_csv=args.metrics_csv)
    state, report = train_loop(step_fn, state, stream, loop_cfg,
                               state_shardings=state_sh,
                               put_batch=put_batch)
    print(f"[train] ran {report.steps_run} steps; "
          f"final loss={report.final_metrics.get('loss'):.4f} "
          f"(resumed_from={report.resumed_from}, "
          f"stragglers={len(report.straggler_steps)})")


if __name__ == "__main__":
    main()
