"""Production mesh builder.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512).

Topology (TPU v5e target):
  single pod : (data=16, model=16)            = 256 chips
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips
The 'model' axis is mapped innermost so TP/EP collectives ride the fast
intra-pod ICI ring; the 'pod' axis crosses the slow inter-pod links and
carries only DP gradient reduction (optionally int8-compressed,
distributed/compress.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over host-platform devices (tests)."""
    return jax.make_mesh(shape, axes)
