"""Public wrapper for the prefix-conflict computation.

Routes between the Pallas kernel (compiled on TPU; interpreter elsewhere)
and a vectorized pure-jnp implementation. On CPU the jnp path is the
default: Pallas interpret mode re-traces the tile loop in Python and is
orders of magnitude slower than one fused XLA elementwise kernel, while on
TPU the tiled Pallas kernel keeps each [B, B] block in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.model import footprint_conflicts
from repro.kernels import ON_TPU
from repro.kernels.conflict.conflict import (
    conflict_block_pallas,
    conflict_matrix_pallas,
)
from repro.obs.profiler import annotate


@functools.partial(jax.jit, static_argnames=("strict",))
def conflict_matrix_jnp(read_ids, write_ids, valid, *, strict: bool = True):
    """Vectorized fallback: the shared hazard algebra (footprint_conflicts)
    broadcast to all pairs, plus the prefix/validity mask."""
    w = read_ids.shape[0]
    conf = footprint_conflicts(
        (read_ids[:, None], write_ids[:, None]),
        (read_ids[None, :], write_ids[None, :]),
        strict=strict,
    )
    lower = jnp.tril(jnp.ones((w, w), dtype=bool), k=-1)
    return conf & lower & valid[:, None] & valid[None, :]


def conflict_matrix(read_ids, write_ids, valid, *, strict: bool = True,
                    backend: str | None = None,
                    interpret: bool | None = None):
    """Prefix-conflict matrix [W, W] (bool) from id footprints.

    read_ids [W, nr] int32, write_ids [W, nw] int32; negative ids are unused
    slots; valid [W] bool masks padded window entries.

    backend: None  — auto: Pallas (compiled) on TPU, jnp elsewhere;
             "pallas" — force the kernel (interpret per ``interpret`` arg,
                        itself auto-detected when None);
             "jnp"    — force the vectorized fallback.
    """
    read_ids = jnp.asarray(read_ids, jnp.int32)
    write_ids = jnp.asarray(write_ids, jnp.int32)
    valid = jnp.asarray(valid, bool)
    if backend is None:
        backend = "pallas" if ON_TPU else "jnp"
    with annotate("protocol.conflict_matrix"):
        if backend == "jnp":
            return conflict_matrix_jnp(read_ids, write_ids, valid,
                                       strict=strict)
        if backend == "pallas":
            out = conflict_matrix_pallas(read_ids, write_ids, valid,
                                         strict=strict, interpret=interpret)
            return out.astype(bool)
    raise ValueError(f"unknown conflict backend {backend!r}")


@functools.partial(jax.jit, static_argnames=("strict",))
def conflict_block_jnp(reads_i, writes_i, reads_j, writes_j,
                       valid_i, valid_j, *, strict: bool = True):
    """Vectorized fallback for the rectangular cross block: the shared
    hazard algebra broadcast over all (later i, earlier j) pairs, masked
    by validity only — no triangular mask, every j precedes every i."""
    conf = footprint_conflicts(
        (reads_i[:, None], writes_i[:, None]),
        (reads_j[None, :], writes_j[None, :]),
        strict=strict,
    )
    return conf & valid_i[:, None] & valid_j[None, :]


def conflict_block(reads_i, writes_i, reads_j, writes_j, valid_i, valid_j,
                   *, strict: bool = True, backend: str | None = None,
                   interpret: bool | None = None):
    """Cross-window conflict block [Wi, Wj] (bool) from id footprints.

    Rows are the *later* window's tasks, columns the *earlier* window's;
    negative ids are unused slots; valid_i/valid_j mask padded entries.
    This is the overlapped engines' carry-over record check — the
    [W_next, W_tail] block between window k+1's head tasks and window
    k's not-yet-drained tail (core/records.cross_window_conflicts).

    backend: None  — auto: Pallas (compiled) on TPU, jnp elsewhere;
             "pallas" — force the rectangular-tile kernel;
             "jnp"    — force the vectorized fallback.
    """
    reads_i = jnp.asarray(reads_i, jnp.int32)
    writes_i = jnp.asarray(writes_i, jnp.int32)
    reads_j = jnp.asarray(reads_j, jnp.int32)
    writes_j = jnp.asarray(writes_j, jnp.int32)
    valid_i = jnp.asarray(valid_i, bool)
    valid_j = jnp.asarray(valid_j, bool)
    if backend is None:
        backend = "pallas" if ON_TPU else "jnp"
    with annotate("protocol.conflict_block"):
        if backend == "jnp":
            return conflict_block_jnp(reads_i, writes_i, reads_j, writes_j,
                                      valid_i, valid_j, strict=strict)
        if backend == "pallas":
            out = conflict_block_pallas(reads_i, writes_i, reads_j, writes_j,
                                        valid_i, valid_j, strict=strict,
                                        interpret=interpret)
            return out.astype(bool)
    raise ValueError(f"unknown conflict backend {backend!r}")
