"""Public wrapper for the prefix-conflict kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_default
from repro.kernels.conflict.conflict import conflict_matrix_pallas


def conflict_matrix(read_ids, write_ids, valid, *, strict: bool = True,
                    interpret: bool | None = None):
    """Prefix-conflict matrix [W, W] (bool) from id footprints.

    read_ids [W, nr] int32, write_ids [W, nw] int32; negative ids are unused
    slots; valid [W] bool masks padded window entries.
    """
    interp = interpret_default() if interpret is None else interpret
    out = conflict_matrix_pallas(
        jnp.asarray(read_ids, jnp.int32),
        jnp.asarray(write_ids, jnp.int32),
        jnp.asarray(valid),
        strict=strict,
        interpret=interp,
    )
    return out.astype(bool)
