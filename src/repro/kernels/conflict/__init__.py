from repro.kernels.conflict.ops import conflict_matrix

__all__ = ["conflict_matrix"]
