"""Pure-jnp oracle for the prefix-conflict kernel.

Footprint model: each task i declares read-ids R_i (shape [W, n_read]) and
write-ids W_i (shape [W, n_write]); an id < 0 is "unused slot".
Later task i conflicts with earlier task j (j < i) iff

    W_j ∩ R_i ≠ ∅                      (flow hazard — the paper's record)
    ∪ (W_j ∩ W_i) ∪ (W_i ∩ R_j) ≠ ∅    when strict (output + anti closure)

which instantiates the paper's Axelrod record rule with R=[src, tgt],
W=[tgt] (there W ⊆ R, so the flow test already covers the output hazard)
and the strict closure of DESIGN.md §10.
"""
from __future__ import annotations

import jax.numpy as jnp


def _any_match(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: [W, na], b: [W, nb] -> [W, W] bool: rows i of a vs rows j of b."""
    eq = a[:, None, :, None] == b[None, :, None, :]     # [W, W, na, nb]
    used = (a[:, None, :, None] >= 0) & (b[None, :, None, :] >= 0)
    return jnp.any(eq & used, axis=(-1, -2))


def conflict_matrix_ref(read_ids, write_ids, valid, *, strict: bool = True):
    """[W, W] bool, strictly lower-triangular prefix-conflict matrix."""
    w = read_ids.shape[0]
    conf = _any_match(read_ids, write_ids)      # W_j ∩ R_i  (i rows, j cols)
    if strict:
        waw = _any_match(write_ids, write_ids)  # W_j ∩ W_i
        war = _any_match(write_ids, read_ids)   # W_i ∩ R_j
        conf = conf | waw | war
    lower = jnp.tril(jnp.ones((w, w), dtype=bool), k=-1)
    return conf & lower & valid[:, None] & valid[None, :]


def conflict_block_ref(reads_i, writes_i, reads_j, writes_j,
                       valid_i, valid_j, *, strict: bool = True):
    """[Wi, Wj] bool cross-window conflict block: rows are the later
    window's tasks, columns the earlier window's. Same hazard algebra as
    the prefix matrix but no triangular mask — every column task precedes
    every row task in chain order."""
    conf = _any_match(reads_i, writes_j)        # W_j ∩ R_i
    if strict:
        conf = conf | _any_match(writes_i, writes_j)   # W_j ∩ W_i
        conf = conf | _any_match(writes_i, reads_j)    # W_i ∩ R_j
    return conf & valid_i[:, None] & valid_j[None, :]
