"""Prefix-conflict matrix Pallas kernel.

The protocol's record check is the O(W²) hot spot of scheduling: for every
pair (i, j<i) of tasks in the window, decide whether task i's id-footprint
intersects task j's write set. On TPU this is a perfectly regular integer
compare over a [W, W] tile grid — VPU work with no MXU involvement, tiled
128×128 so each block's operands live in VMEM:

  per (bi, bj) tile:
    rows: read_ids[bi·B : , :nr], write_ids[bi·B : , :nw]   (task i side)
    cols: read_ids[bj·B : , :nr], write_ids[bj·B : , :nw]   (task j side)
    out:  conflict int32 block [B, B]

The matrix is strictly lower-triangular, so tiles strictly above the block
diagonal (bj > bi) are identically zero. The grid is therefore a 1-D walk
over the n(n+1)/2 tiles with bj <= bi (n = W/B tile rows), with the
(bi, bj) coordinates of each step delivered through scalar-prefetch lookup
tables — instead of the dense n² grid, a 2× tile-count reduction at large
W (e.g. W=1024, B=128: 36 tiles instead of 64; W=4096: 528 instead of
1024). The never-visited upper tiles hold uninitialized memory and are
zeroed by one fused elementwise mask after the kernel (the in-kernel
global-index mask still handles the diagonal tiles' upper halves and the
padded tail, so visited tiles come out exactly as the dense grid produced
them — bit-identical by construction and by test).

Hazard semantics (shared repo-wide; see core/model.py):

  strict=False — the paper's record rule: the record accumulates the write
      sets of skipped tasks and tests them against the task at hand's READ
      set, i.e. flow (RAW) hazards:      W_j ∩ R_i ≠ ∅.
      (For models whose write ids also appear among their read ids — e.g.
      Axelrod, where the target's traits are read to compute the overlap —
      this equals the paper's flow+output statement exactly.)
  strict=True  — full dependence closure: adds output (WAW) W_j ∩ W_i and
      anti (WAR) W_i ∩ R_j hazards; the only rule that is bit-exact vs
      sequential execution.

Windows that are not a multiple of the tile size are padded up with -1 ids
and invalid slots (masked in-kernel via w_total), then sliced back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK = 128


def _hazard_tile(nr_i: int, nw_i: int, nr_j: int, nw_j: int, strict: bool,
                 reads_i, writes_i, reads_j, writes_j, bi, bj):
    """Shared hazard algebra for one [Bi, Bj] tile (rows = later task i,
    cols = earlier task j): flow W_j ∩ R_i, plus output W_j ∩ W_i and
    anti W_i ∩ R_j under the strict closure. Pure VPU integer compares;
    used by both the triangular prefix kernel and the rectangular
    cross-window block kernel."""
    conf = jnp.zeros((bi, bj), dtype=jnp.bool_)

    # flow (RAW): write_j ∈ reads_i
    for a in range(nw_j):
        wj = writes_j[:, a][None, :]          # [1, Bj] earlier-task writes
        uj = wj >= 0
        for c in range(nr_i):
            ri = reads_i[:, c][:, None]       # [Bi, 1]
            conf |= (ri == wj) & uj & (ri >= 0)
        if strict:
            # output (WAW): write_j ∈ writes_i
            for c in range(nw_i):
                wi = writes_i[:, c][:, None]
                conf |= (wi == wj) & uj & (wi >= 0)

    if strict:
        # anti (WAR): write_i ∈ reads_j
        for a in range(nw_i):
            wi = writes_i[:, a][:, None]      # [Bi, 1]
            ui = wi >= 0
            for c in range(nr_j):
                rj = reads_j[:, c][None, :]   # [1, Bj]
                conf |= (wi == rj) & ui & (rj >= 0)
    return conf


def _kernel(nr: int, nw: int, strict: bool, w_total: int,
            bi_ref, bj_ref,
            reads_i, writes_i, reads_j, writes_j, valid_i, valid_j, out_ref):
    t = pl.program_id(0)
    bi = bi_ref[t]
    bj = bj_ref[t]
    b = out_ref.shape[0]

    gi = bi * b + jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)  # global i
    gj = bj * b + jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)  # global j

    conf = _hazard_tile(nr, nw, nr, nw, strict,
                        reads_i, writes_i, reads_j, writes_j, b, b)

    mask = (gj < gi) & (gi < w_total) & (gj < w_total)
    mask &= (valid_i[:, :1] != 0) & (valid_j[:, :1].T != 0)
    out_ref[...] = (conf & mask).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("strict", "interpret", "block"))
def conflict_matrix_pallas(read_ids, write_ids, valid, *, strict: bool = True,
                           interpret: bool | None = None, block: int = BLOCK):
    """read_ids [W, nr] int32, write_ids [W, nw] int32 (−1 = unused slot),
    valid [W] bool. Returns [W, W] int32 prefix-conflict matrix.

    interpret=None auto-detects the backend: compiled on TPU, Pallas
    interpreter elsewhere. Any window size is accepted; non-multiples of
    the tile size are padded to the next tile boundary internally.
    """
    if interpret is None:
        from repro.kernels import interpret_default

        interpret = interpret_default()
    w, nr = read_ids.shape
    nw = write_ids.shape[1]
    b = min(block, w)
    w_pad = -(-w // b) * b  # next multiple of the tile size
    if w_pad != w:
        pad = ((0, w_pad - w), (0, 0))
        read_ids = jnp.pad(read_ids, pad, constant_values=-1)
        write_ids = jnp.pad(write_ids, pad, constant_values=-1)
        valid = jnp.pad(valid, (0, w_pad - w), constant_values=False)
    n = w_pad // b
    # 1-D triangular tile walk (bj <= bi), coordinates via scalar prefetch
    bi_map, bj_map = (np.asarray(x, np.int32) for x in zip(
        *[(bi, bj) for bi in range(n) for bj in range(bi + 1)]))
    valid_i32 = valid.astype(jnp.int32)[:, None]  # [W, 1] for clean tiling

    row_spec = pl.BlockSpec((b, nr), lambda t, bi, bj: (bi[t], 0))
    col_spec = pl.BlockSpec((b, nr), lambda t, bi, bj: (bj[t], 0))
    roww_spec = pl.BlockSpec((b, nw), lambda t, bi, bj: (bi[t], 0))
    colw_spec = pl.BlockSpec((b, nw), lambda t, bi, bj: (bj[t], 0))
    vrow_spec = pl.BlockSpec((b, 1), lambda t, bi, bj: (bi[t], 0))
    vcol_spec = pl.BlockSpec((b, 1), lambda t, bi, bj: (bj[t], 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(len(bi_map),),
        in_specs=[row_spec, roww_spec, col_spec, colw_spec,
                  vrow_spec, vcol_spec],
        out_specs=pl.BlockSpec((b, b), lambda t, bi, bj: (bi[t], bj[t])),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, nr, nw, strict, w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((w_pad, w_pad), jnp.int32),
        interpret=interpret,
    )(jnp.asarray(bi_map), jnp.asarray(bj_map),
      read_ids, write_ids, read_ids, write_ids, valid_i32, valid_i32)
    # zero the never-visited tiles strictly above the block diagonal
    lower = jnp.tril(jnp.ones((w_pad, w_pad), dtype=bool), k=-1)
    return jnp.where(lower, out, 0)[:w, :w]


def _block_kernel(nr_i: int, nw_i: int, nr_j: int, nw_j: int, strict: bool,
                  wi_total: int, wj_total: int,
                  reads_i, writes_i, reads_j, writes_j,
                  valid_i, valid_j, out_ref):
    bi, bj = out_ref.shape

    gi = (pl.program_id(0) * bi
          + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 0))
    gj = (pl.program_id(1) * bj
          + jax.lax.broadcasted_iota(jnp.int32, (bi, bj), 1))

    conf = _hazard_tile(nr_i, nw_i, nr_j, nw_j, strict,
                        reads_i, writes_i, reads_j, writes_j, bi, bj)

    # full rectangle: every j-side task precedes every i-side task, so
    # there is no triangular/prefix mask — only padding and validity
    mask = (gi < wi_total) & (gj < wj_total)
    mask &= (valid_i[:, :1] != 0) & (valid_j[:, :1].T != 0)
    out_ref[...] = (conf & mask).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("strict", "interpret", "block"))
def conflict_block_pallas(reads_i, writes_i, reads_j, writes_j,
                          valid_i, valid_j, *, strict: bool = True,
                          interpret: bool | None = None, block: int = BLOCK):
    """Rectangular cross-window conflict block [Wi, Wj] int32.

    Rows are the *later* window's tasks (reads_i [Wi, nr_i] / writes_i
    [Wi, nw_i]), columns the *earlier* window's (reads_j [Wj, nr_j] /
    writes_j [Wj, nw_j]); -1 ids are unused slots, valid_* mask padded
    window entries. Because the two sides come from different windows,
    every column task precedes every row task in chain order, so — unlike
    the triangular prefix kernel — the tile grid is the full [Wi/B, Wj/B]
    rectangle and no prefix mask applies. This is the overlapped engines'
    carry-over record check (core/records.cross_window_conflicts).
    """
    if interpret is None:
        from repro.kernels import interpret_default

        interpret = interpret_default()
    wi, nr_i = reads_i.shape
    wj, nr_j = reads_j.shape
    nw_i, nw_j = writes_i.shape[1], writes_j.shape[1]
    b_i, b_j = min(block, wi), min(block, wj)
    wi_pad, wj_pad = -(-wi // b_i) * b_i, -(-wj // b_j) * b_j

    def _pad(x, w_pad):
        w = x.shape[0]
        return (x if w_pad == w else
                jnp.pad(x, ((0, w_pad - w), (0, 0)), constant_values=-1))

    reads_i, writes_i = _pad(reads_i, wi_pad), _pad(writes_i, wi_pad)
    reads_j, writes_j = _pad(reads_j, wj_pad), _pad(writes_j, wj_pad)
    vi = jnp.pad(valid_i.astype(jnp.int32), (0, wi_pad - wi))[:, None]
    vj = jnp.pad(valid_j.astype(jnp.int32), (0, wj_pad - wj))[:, None]

    out = pl.pallas_call(
        functools.partial(_block_kernel, nr_i, nw_i, nr_j, nw_j,
                          strict, wi, wj),
        grid=(wi_pad // b_i, wj_pad // b_j),
        in_specs=[pl.BlockSpec((b_i, nr_i), lambda i, j: (i, 0)),
                  pl.BlockSpec((b_i, nw_i), lambda i, j: (i, 0)),
                  pl.BlockSpec((b_j, nr_j), lambda i, j: (j, 0)),
                  pl.BlockSpec((b_j, nw_j), lambda i, j: (j, 0)),
                  pl.BlockSpec((b_i, 1), lambda i, j: (i, 0)),
                  pl.BlockSpec((b_j, 1), lambda i, j: (j, 0))],
        out_specs=pl.BlockSpec((b_i, b_j), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((wi_pad, wj_pad), jnp.int32),
        interpret=interpret,
    )(reads_i, writes_i, reads_j, writes_j, vi, vj)
    return out[:wi, :wj]
