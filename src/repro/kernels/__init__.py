"""Pallas TPU kernels for the compute hot-spots.

Each subpackage ships three files:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrapper (interpret=True fallback on CPU)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

Kernels:
  conflict — W×W prefix-conflict bitmask over task id-footprints (the
             protocol's O(W²) record check, paper §3.5); triangular
             1-D tile walk via scalar prefetch
  levels   — blocked wave-level assignment over the conflict matrix
             (replaces the per-task scan on the scheduling path)
  axelrod  — one wave of pairwise cultural interactions (paper §4.1)
  sir      — one wave of ring-graph SIRS subset updates (paper §4.2)
  wkv6     — RWKV6 data-dependent-decay time-mix (chunked recurrence)
  flash    — fused attention (causal / sliding-window), online softmax
"""

ON_TPU = False
try:  # pragma: no cover - resolved at import time
    import jax

    ON_TPU = jax.default_backend() == "tpu"
except Exception:  # pragma: no cover
    pass


def interpret_default() -> bool:
    """pallas interpret mode: Python interpreter on CPU, compiled on TPU."""
    return not ON_TPU
