"""Fused attention Pallas kernel (flash-attention, TPU layout).

Design for the TPU memory hierarchy:

  * grid = (B·H, T/bq, S/bk); the kv axis is the innermost, *sequential*
    dimension (dimension_semantics "arbitrary") carrying the online-softmax
    running state (m, l, acc) in VMEM scratch across kv blocks.
  * blocks: q [bq, D], k/v [bk, D] with bq = bk = 128 — MXU-aligned matmul
    dims (128×D×128); the two matmuls per tile hit the MXU, masking and the
    online-softmax rescale run on the VPU in f32.
  * GQA is resolved in the k/v BlockSpec index maps (query head h reads kv
    head h // group) — no repeat/materialization of kv in HBM.
  * causal + sliding-window masks are computed from global indices; tiles
    that the mask would zero entirely are skipped with pl.when (the grid
    still visits them, but neither matmul executes — the hillclimb log
    discusses replacing this with a shortened kv grid per q block).

The q block is aligned to the *end* of the key axis when S > T, which gives
chunked-prefill/decode semantics for free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils.compat import tpu_compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(causal: bool, window: int | None, scale: float, seq_off: int,
            n_kv_blocks: int,
            q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    bq = q_ref.shape[1]
    bk = k_ref.shape[1]

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # global positions (q offset by seq_off = S - T: ends aligned)
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + seq_off
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # tile-level skip test (static per (qi, kj) given causal/window)
    q_first = qi * bq + seq_off
    q_last = q_first + bq - 1
    k_first = kj * bk
    k_last = k_first + bk - 1
    live = True
    if causal:
        live = jnp.logical_and(live, k_first <= q_last)
    if window is not None:
        live = jnp.logical_and(live, k_last > q_first - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale       # [bq, bk]

        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                                    # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                                 # [bq, bk]
        correction = jnp.exp(m_prev - m_new)                   # [bq, 1]
        l_ref[...] = l_ref[...] * correction + jnp.sum(
            p, axis=-1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)                # [bq, D]
        acc_ref[...] = acc_ref[...] * correction + pv
        m_ref[...] = m_new

    @pl.when(kj == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)  # fully-masked rows -> zeros
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "n_q_heads", "n_kv_heads",
                     "interpret", "block_q", "block_k"))
def flash_attention_pallas(q, k, v, *, causal: bool, window: int | None,
                           scale: float, n_q_heads: int, n_kv_heads: int,
                           interpret: bool = True,
                           block_q: int = DEFAULT_BLOCK_Q,
                           block_k: int = DEFAULT_BLOCK_K):
    """q [B·H, T, D]; k, v [B·Hkv, S, D]. Returns o [B·H, T, D]."""
    bh, t, d = q.shape
    s = k.shape[1]
    bq = min(block_q, t)
    bk = min(block_k, s)
    assert t % bq == 0 and s % bk == 0, (t, s, bq, bk)
    group = n_q_heads // n_kv_heads
    grid = (bh, t // bq, s // bk)
    seq_off = s - t

    def kv_map(b, i, j):
        batch = b // n_q_heads
        head = b % n_q_heads
        return (batch * n_kv_heads + head // group, j, 0)

    kernel = functools.partial(_kernel, causal, window, scale, seq_off,
                               s // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
