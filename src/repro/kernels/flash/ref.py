"""Pure-jnp oracle for fused attention (causal / sliding-window / full)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True,
                  window: int | None = None, scale: float | None = None):
    """q [B, H, T, D]; k, v [B, Hkv, S, D] with H % Hkv == 0 (GQA).

    window w: query t attends to keys in (t-w, t] (requires causal).
    When S > T the query block is aligned to the *end* of the key axis
    (chunked prefill / decode semantics).
    Returns [B, H, T, D] in q's dtype; softmax accumulates in f32.
    """
    b, h, t, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    if scale is None:
        scale = d ** -0.5

    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)

    logits = jnp.einsum("bhtd,bhsd->bhts", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    s = kk.shape[2]
    qi = jnp.arange(t)[:, None] + (s - t)   # align ends (prefill/decode)
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((t, s), bool)
    if causal:
        mask &= ki <= qi
    if window is not None:
        mask &= ki > qi - window
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhts,bhsd->bhtd", p, vv.astype(jnp.float32))
    return out.astype(q.dtype)
