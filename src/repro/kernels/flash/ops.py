"""Public wrapper for the fused attention kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_default
from repro.kernels.flash.flash import flash_attention_pallas


def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    scale: float | None = None, interpret: bool | None = None,
                    block_q: int = 128, block_k: int = 128):
    """q [B, H, T, D]; k, v [B, Hkv, S, D] (GQA via H % Hkv == 0).

    Sliding ``window`` w: query t attends keys (t-w, t]; requires causal.
    Ends are aligned when S > T (chunked prefill semantics).
    """
    interp = interpret_default() if interpret is None else interpret
    b, h, t, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    if scale is None:
        scale = float(d) ** -0.5

    qf = q.reshape(b * h, t, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)
    o = flash_attention_pallas(
        qf, kf, vf, causal=causal, window=window, scale=scale,
        n_q_heads=h, n_kv_heads=hkv, interpret=interp,
        block_q=block_q, block_k=block_k)
    return o.reshape(b, h, t, d)
