from repro.kernels.axelrod.ops import axelrod_wave

__all__ = ["axelrod_wave"]
