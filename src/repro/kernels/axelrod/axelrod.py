"""Axelrod wave-interaction Pallas kernel.

One wave = up to W commuting pairwise interactions. The per-pair work is the
paper's task-size knob (s = F features): an integer compare-reduce over F,
a bounded-confidence gate, and a one-feature masked copy. On TPU this is
pure VPU work; the kernel tiles rows (pairs) in blocks of 128 and keeps the
whole (padded) feature axis resident in VMEM — for the paper's F ≤ 500 a
[128, Fp] block is ≤ 128·512·4 B = 256 KiB, comfortably inside the ~16 MiB
VMEM budget together with its five operands.

Gather (traits[src]) and scatter (traits[tgt]) remain outside the kernel:
XLA's dynamic-gather is already optimal for rows of this size, and keeping
the kernel pure on [W, Fp] blocks makes it fully shape-static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_W = 128


def _kernel(omega: float, n_features: int,
            s_ref, t_ref, u_ref, g_ref, m_ref, out_ref, inter_ref):
    fp = s_ref.shape[1]
    s_tr = s_ref[...]
    t_tr = t_ref[...]
    valid_f = jax.lax.broadcasted_iota(jnp.int32, (1, fp), 1) < n_features

    eq = (s_tr == t_tr) & valid_f
    overlap = (jnp.sum(eq.astype(jnp.float32), axis=-1, keepdims=True)
               / n_features)                                     # [B, 1]

    u = u_ref[...]                                               # [B, 1]
    mask = m_ref[...] != 0
    interact = (
        mask & (u < overlap) & (overlap < 1.0) & (overlap >= 1.0 - omega)
    )                                                            # [B, 1]

    # pick one differing feature uniformly — gumbel argmax realized as a
    # max-compare one-hot (argmax along lanes is awkward on TPU; comparing
    # against the row max vectorizes cleanly). Ties break to the *first*
    # maximum via a lane cumsum, exactly matching jnp.argmax semantics.
    g = g_ref[...]
    scores = jnp.where((~eq) & valid_f, g, -1.0)
    row_max = jnp.max(scores, axis=-1, keepdims=True)
    at_max = (scores == row_max) & (scores > -0.5)
    first = jnp.cumsum(at_max.astype(jnp.int32), axis=-1) == 1
    onehot = at_max & first

    out_ref[...] = jnp.where(onehot & interact, s_tr, t_tr)
    inter_ref[...] = interact.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("omega", "n_features", "interpret",
                                    "block"))
def axelrod_wave_pallas(s_tr, t_tr, u, gumbel, mask, *, omega: float,
                        n_features: int, interpret: bool = True,
                        block: int = BLOCK_W):
    w, fp = s_tr.shape
    b = min(block, w)
    assert w % b == 0
    grid = (w // b,)

    row2 = lambda i: (i, 0)
    return pl.pallas_call(
        functools.partial(_kernel, omega, n_features),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, fp), row2),
            pl.BlockSpec((b, fp), row2),
            pl.BlockSpec((b, 1), row2),
            pl.BlockSpec((b, fp), row2),
            pl.BlockSpec((b, 1), row2),
        ],
        out_specs=[
            pl.BlockSpec((b, fp), row2),
            pl.BlockSpec((b, 1), row2),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w, fp), jnp.int32),
            jax.ShapeDtypeStruct((w, 1), jnp.int32),
        ],
        interpret=interpret,
    )(s_tr, t_tr, u[:, None], gumbel, mask[:, None].astype(jnp.int32))
