"""Pure-jnp oracle for the Axelrod wave-interaction kernel."""
from __future__ import annotations

import jax.numpy as jnp


def axelrod_wave_ref(s_tr, t_tr, u, gumbel, mask, *, omega: float,
                     n_features: int):
    """One wave of pairwise interactions.

    s_tr, t_tr: [W, Fp] int32 (source / target traits, Fp >= n_features,
    padding columns ignored); u: [W] f32; gumbel: [W, Fp] f32; mask [W] bool.
    Returns (new_t [W, Fp] int32, interact [W] bool).
    """
    fp = s_tr.shape[1]
    valid_f = jnp.arange(fp) < n_features

    eq = (s_tr == t_tr) & valid_f
    overlap = jnp.sum(eq, axis=-1).astype(jnp.float32) / n_features

    interact = (
        mask & (u < overlap) & (overlap < 1.0) & (overlap >= 1.0 - omega)
    )

    scores = jnp.where((~eq) & valid_f, gumbel, -1.0)
    feat = jnp.argmax(scores, axis=-1)                      # [W]

    onehot = jnp.arange(fp)[None, :] == feat[:, None]
    new_t = jnp.where(onehot & interact[:, None], s_tr, t_tr)
    return new_t, interact
