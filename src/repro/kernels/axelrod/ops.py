"""Public wrapper for the Axelrod wave kernel (gather/scatter outside)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_default
from repro.kernels.axelrod.axelrod import axelrod_wave_pallas


def _pad_features(x, fp):
    f = x.shape[1]
    if f == fp:
        return x
    pad = [(0, 0), (0, fp - f)]
    return jnp.pad(x, pad)


def axelrod_wave(s_tr, t_tr, u, gumbel, mask, *, omega: float,
                 interpret: bool | None = None):
    """Kernel-backed wave interaction. Returns (new_t [W, F], interact [W]).

    Accepts unpadded [W, F]; pads the feature axis to a lane multiple of 128
    for the TPU layout and crops on return.
    """
    interp = interpret_default() if interpret is None else interpret
    w, f = s_tr.shape
    fp = max(128, -(-f // 128) * 128)
    new_t, inter = axelrod_wave_pallas(
        _pad_features(s_tr.astype(jnp.int32), fp),
        _pad_features(t_tr.astype(jnp.int32), fp),
        u.astype(jnp.float32),
        _pad_features(gumbel.astype(jnp.float32), fp),
        mask,
        omega=omega,
        n_features=f,
        interpret=interp,
    )
    return new_t[:, :f], inter[:, 0].astype(bool)
