"""RWKV6 time-mix Pallas kernel — chunked data-dependent-decay recurrence.

This is the classic "recurrence that deserves a kernel" (the RWKV project
ships a CUDA kernel for it). TPU-native rethink: instead of a per-timestep
CUDA thread loop, we use the chunked-scan formulation —

  grid = (B·H, T/L); the chunk axis is sequential ("arbitrary"), carrying
  the inter-chunk state S [D, D] in f32 VMEM scratch.

Per chunk of length L, with per-channel log-decays lw_t = log w_t < 0 and
inclusive cumsums s_t = Σ_{j<=t} lw_j (monotone decreasing):

  state term   o  += (r_t ⊙ e^{s_{t-1}}) @ S0                (MXU matmul;
               exponents <= 0, numerically safe)
  intra term   A[t,i<t] = Σ_d r[t,d] k[i,d] e^{s_{t-1,d} - s_{i,d}}
               A[t,t]   = Σ_d r[t,d] u[d]  k[t,d]
               o  += A @ V                                    (MXU matmul)
  state update S <- diag(e^{s_L}) S0 + (k ⊙ e^{s_L - s})ᵀ @ V (MXU matmul)

All exponents are differences s_a - s_b with a >= b along time, hence <= 0:
the chunked form is stable without log-space max-subtraction games. The
intra-chunk A is computed blockwise: off-diagonal sub-blocks factor through
a boundary reference (two stable matmuls); diagonal sub-blocks are computed
directly as an [l, l, D] masked contraction (VPU).

VMEM budget per grid step (L=128, D=64, f32): r/k/v/w blocks 4·32 KiB,
S scratch 16 KiB, A 64 KiB, sub-block temporaries < 128 KiB — well under
the ~16 MiB budget, leaving room for double-buffered pipelines.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.utils.compat import tpu_compiler_params

DEFAULT_CHUNK = 128
SUB = 32  # diagonal sub-block length


def _kernel(n_heads: int, chunk: int,
            r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, sfin_ref, s_ref):
    c = pl.program_id(1)
    L = chunk
    d = r_ref.shape[-1]

    @pl.when(c == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)          # [L, D]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # [1, D] block -> [D]

    lw = jnp.log(w)                           # < 0
    s_incl = jnp.cumsum(lw, axis=0)           # [L, D] decreasing
    s_excl = s_incl - lw

    S0 = s_ref[...]                           # [D, D]

    # ---- state term: (r ⊙ e^{s_excl}) @ S0 ----
    q = r * jnp.exp(s_excl)
    o = jax.lax.dot_general(q, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # [L, D]

    # ---- intra-chunk A ----
    a = jnp.zeros((L, L), jnp.float32)
    n_sub = L // SUB
    for bi in range(n_sub):          # row (later) sub-block
        t0 = bi * SUB
        # boundary reference: s at the *start* of row block (exclusive)
        s_ref_row = s_excl[t0]                          # [D]
        q_b = (r[t0:t0 + SUB] * jnp.exp(s_excl[t0:t0 + SUB] - s_ref_row))
        for bj in range(bi):         # strictly-earlier column sub-blocks
            i0 = bj * SUB
            k_b = (k[i0:i0 + SUB] * jnp.exp(s_ref_row - s_incl[i0:i0 + SUB]))
            blk = jax.lax.dot_general(
                q_b, k_b, (((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)     # [SUB, SUB]
            a = jax.lax.dynamic_update_slice(a, blk, (t0, i0))
        # diagonal sub-block: direct masked contraction + u-bonus diag
        rd = r[t0:t0 + SUB]                              # [l, D]
        kd = k[t0:t0 + SUB]
        se = s_excl[t0:t0 + SUB]
        si = s_incl[t0:t0 + SUB]
        expdiff = jnp.exp(se[:, None, :] - si[None, :, :])  # [l, l, D]
        blk = jnp.sum(rd[:, None, :] * kd[None, :, :] * expdiff, axis=-1)
        tri = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 0) > \
            jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 1)
        diag_val = jnp.sum(rd * u * kd, axis=-1)         # [l]
        eye = jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 0) == \
            jax.lax.broadcasted_iota(jnp.int32, (SUB, SUB), 1)
        blk = jnp.where(tri, blk, 0.0) + jnp.where(eye, diag_val[:, None], 0.0)
        a = jax.lax.dynamic_update_slice(a, blk, (t0, t0))

    o = o + jax.lax.dot_general(a, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)

    # ---- state update ----
    total = s_incl[L - 1]                                # [D]
    k_dec = k * jnp.exp(total[None, :] - s_incl)         # [L, D]
    s_new = (jnp.exp(total)[:, None] * S0
             + jax.lax.dot_general(k_dec, v, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    s_ref[...] = s_new
    o_ref[0] = o.astype(o_ref.dtype)
    # constant block index along the sequential axis: the last write wins,
    # so emitting every step is safe on TPU and in interpret mode alike
    sfin_ref[0] = s_new


@functools.partial(
    jax.jit, static_argnames=("n_heads", "interpret", "chunk"))
def wkv6_pallas(r, k, v, w, u, *, n_heads: int, interpret: bool = True,
                chunk: int = DEFAULT_CHUNK):
    """r/k/v/w: [B·H, T, D]; u: [H, D]. Returns (o [B·H, T, D] f32,
    s_final [B·H, D, D] f32)."""
    bh, t, d = r.shape
    L = min(chunk, t)
    assert t % L == 0, (t, L)
    assert L % SUB == 0, (L, SUB)
    grid = (bh, t // L)

    tmap = lambda b, c: (b, c, 0)
    umap = lambda b, c: (b % n_heads, 0)

    return pl.pallas_call(
        functools.partial(_kernel, n_heads, L),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, L, d), tmap),
            pl.BlockSpec((1, L, d), tmap),
            pl.BlockSpec((1, L, d), tmap),
            pl.BlockSpec((1, L, d), tmap),
            pl.BlockSpec((1, d), umap),
        ],
        out_specs=[
            pl.BlockSpec((1, L, d), tmap),
            pl.BlockSpec((1, d, d), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, t, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, d, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(r, k, v, w, u)
