"""Public wrapper for the WKV6 kernel + the O(1) decode-step path."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_default
from repro.kernels.wkv6.wkv6 import wkv6_pallas


def wkv6(r, k, v, w, u, *, interpret: bool | None = None, chunk: int = 128):
    """RWKV6 time-mix. r/k/v/w [B, H, T, D]; u [H, D].

    Returns (o [B, H, T, D] f32, s_final [B, H, D, D] f32).
    """
    interp = interpret_default() if interpret is None else interpret
    b, h, t, d = r.shape
    flat = lambda x: x.reshape(b * h, t, d)
    ch = chunk
    while t % ch != 0 or ch % 32 != 0:
        ch //= 2
        if ch < 32:
            ch = t  # fall back to single chunk (t must be mult of SUB=32)
            break
    o, s_fin = wkv6_pallas(flat(r), flat(k), flat(v), flat(w), u,
                           n_heads=h, interpret=interp, chunk=ch)
    return o.reshape(b, h, t, d), s_fin.reshape(b, h, d, d)


def wkv6_decode_step(s, r, k, v, w, u):
    """One-token recurrence for serving. s [B, H, D, D]; r/k/v/w [B, H, D];
    u [H, D]. Returns (o [B, H, D], s_next)."""
    sf = s.astype(jnp.float32)
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)
    bonus = jnp.sum(rf * uf[None] * kf, axis=-1, keepdims=True)  # [B,H,1]
    o = jnp.einsum("bhk,bhkd->bhd", rf, sf) + bonus * vf
    s_next = wf[..., None] * sf + kf[..., None] * vf[..., None, :]
    return o, s_next
