"""Pure-jnp oracle for the RWKV6 (Finch) time-mix recurrence.

Per head with state S ∈ R^{D×D} (key-dim × value-dim), data-dependent
per-channel decay w_t ∈ (0,1)^D and bonus u ∈ R^D:

    o_t = r_t @ S  +  (Σ_d r_t[d]·u[d]·k_t[d]) · v_t
    S  <- diag(w_t) @ S + k_t ⊗ v_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r, k, v, w, u, *, s0=None):
    """r, k, v, w: [B, H, T, D]; u: [H, D].

    Returns (o [B, H, T, D] (f32), s_final [B, H, D, D] (f32)).
    """
    b, h, t, d = r.shape
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def per_head(r1, k1, v1, w1, u1, s_init):
        def step(S, xs):
            rt, kt, vt, wt = xs
            bonus = jnp.sum(rt * u1 * kt)
            ot = rt @ S + bonus * vt
            S = wt[:, None] * S + jnp.outer(kt, vt)
            return S, ot

        s_fin, o = jax.lax.scan(step, s_init, (r1, k1, v1, w1))
        return o, s_fin

    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)
    else:
        s0 = s0.astype(jnp.float32)

    o, s_fin = jax.vmap(           # over batch
        jax.vmap(per_head, in_axes=(0, 0, 0, 0, 0, 0)),  # over heads
        in_axes=(0, 0, 0, 0, None, 0),
    )(rf, kf, vf, wf, uf, s0)
    return o, s_fin
