from repro.kernels.wkv6.ops import wkv6

__all__ = ["wkv6"]
