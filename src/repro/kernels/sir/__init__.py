from repro.kernels.sir.ops import sir_wave

__all__ = ["sir_wave"]
