"""SIR subset-update Pallas kernel.

One wave = up to W commuting type-A tasks, each updating one contiguous
subset of s agents on the ring. Because the graph is a ring of constant
degree k, the neighbourhood of a contiguous subset is a contiguous slice of
length s + k — so the "gather" is a halo exchange, not a real gather, and
inside the kernel the k neighbour reads become k static shifted slices of a
VMEM-resident row (classic stencil pattern; this is the TPU-native rethink
of the paper's per-agent neighbour iteration).

Tiling: rows (tasks) in blocks of 8; the (padded) agent axis stays whole in
VMEM: block = [8, sp + kp] ints ≤ 8·(1024+128)·4 B ≈ 36 KiB. The k shifted
compares are VPU adds; there is no MXU work in this model.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_W = 8
S, I, R = 0, 1, 2


def _kernel(k: int, s: int, p_si: float, p_ir: float, p_rs: float,
            ext_ref, u_ref, out_ref):
    half = k // 2
    ext = ext_ref[...]

    acc = jnp.zeros((ext.shape[0], s), jnp.float32)
    for d in range(2 * half + 1):
        if d == half:
            continue
        acc = acc + (jax.lax.slice_in_dim(ext, d, d + s, axis=1) == I
                     ).astype(jnp.float32)
    inf_frac = acc / k

    cur = jax.lax.slice_in_dim(ext, half, half + s, axis=1)
    u = jax.lax.slice_in_dim(u_ref[...], 0, s, axis=1)

    nxt = jnp.where(
        (cur == S) & (u < p_si * inf_frac), I,
        jnp.where(
            (cur == I) & (u < p_ir), R,
            jnp.where((cur == R) & (u < p_rs), S, cur),
        ),
    )
    # write back into the padded output row
    padded = jnp.zeros(out_ref.shape, jnp.int32)
    padded = jax.lax.dynamic_update_slice(padded, nxt.astype(jnp.int32),
                                          (0, 0))
    out_ref[...] = padded


@functools.partial(
    jax.jit,
    static_argnames=("k", "subset_size", "p_si", "p_ir", "p_rs",
                     "interpret", "block"))
def sir_wave_pallas(ext_states, u, *, k: int, subset_size: int, p_si: float,
                    p_ir: float, p_rs: float, interpret: bool = True,
                    block: int = BLOCK_W):
    w, ep = ext_states.shape
    up = u.shape[1]
    b = min(block, w)
    assert w % b == 0
    grid = (w // b,)
    row = lambda i: (i, 0)

    return pl.pallas_call(
        functools.partial(_kernel, k, subset_size, p_si, p_ir, p_rs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, ep), row),
            pl.BlockSpec((b, up), row),
        ],
        out_specs=pl.BlockSpec((b, up), row),
        out_shape=jax.ShapeDtypeStruct((w, up), jnp.int32),
        interpret=interpret,
    )(ext_states, u)
