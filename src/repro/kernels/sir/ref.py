"""Pure-jnp oracle for the SIR subset-update kernel."""
from __future__ import annotations

import jax.numpy as jnp

S, I, R = 0, 1, 2


def sir_wave_ref(ext_states, u, *, k: int, subset_size: int,
                 p_si: float, p_ir: float, p_rs: float):
    """One wave of subset state-computations (the protocol's type-A tasks).

    ext_states: [W, s + k(+pad)] int32 — the contiguous ring slice covering
        each subset plus k/2 halo cells on each side (ops.py gathers it).
    u: [W, s(+pad)] f32 — per-agent uniforms (bound at task creation).
    Returns nxt [W, s] int32 — the agents' next states.
    """
    half = k // 2
    s = subset_size
    # infected-neighbour count via the 2·half static shifts of the halo row
    acc = jnp.zeros(ext_states[:, :s].shape, jnp.float32)
    for d in range(2 * half + 1):
        if d == half:
            continue  # skip self
        acc = acc + (ext_states[:, d:d + s] == I).astype(jnp.float32)
    inf_frac = acc / k

    cur = ext_states[:, half:half + s]
    uu = u[:, :s]
    nxt = jnp.where(
        (cur == S) & (uu < p_si * inf_frac), I,
        jnp.where(
            (cur == I) & (uu < p_ir), R,
            jnp.where((cur == R) & (uu < p_rs), S, cur),
        ),
    )
    return nxt.astype(jnp.int32)
