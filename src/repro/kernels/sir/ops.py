"""Public wrapper for the SIR wave kernel (halo gather outside)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_default
from repro.kernels.sir.sir import sir_wave_pallas


def _pad_to(x, n):
    if x.shape[1] == n:
        return x
    return jnp.pad(x, [(0, 0), (0, n - x.shape[1])])


def sir_wave(states, subsets, u, *, n_agents: int, k: int, subset_size: int,
             p_si: float, p_ir: float, p_rs: float,
             interpret: bool | None = None):
    """Kernel-backed type-A wave.

    states [N] int — ring states; subsets [W] int32 — subset ids;
    u [W, s] f32. Returns nxt [W, s] int32 next states per subset agent.
    """
    interp = interpret_default() if interpret is None else interpret
    half = k // 2
    s = subset_size

    # halo slice per subset: contiguous on the ring
    base = subsets[:, None] * s - half
    idx = (base + jnp.arange(s + 2 * half)[None, :]) % n_agents
    ext = states[idx].astype(jnp.int32)                     # [W, s+k]

    ep = max(128, -(-(s + 2 * half) // 128) * 128)
    up = max(128, -(-s // 128) * 128)
    nxt = sir_wave_pallas(
        _pad_to(ext, ep),
        _pad_to(u.astype(jnp.float32), up),
        k=k, subset_size=s, p_si=p_si, p_ir=p_ir, p_rs=p_rs,
        interpret=interp,
    )
    return nxt[:, :s]
