"""Wave-level (dependence-level) assignment kernel.

Turns the [W, W] prefix-conflict matrix into per-task wavefront levels —
the remaining sequential O(W) stage on the scheduling path after the
conflict matrix itself went on the tiled Pallas kernel. The Pallas
implementation walks the B diagonal blocks sequentially and vectorizes
everything else over [B, W] row panels; the pure-jnp reference keeps the
original per-task ``lax.scan``.
"""
from repro.kernels.levels.ops import wave_levels
from repro.kernels.levels.ref import wave_levels_ref

__all__ = ["wave_levels", "wave_levels_ref"]
