"""Pure-jnp oracle for wave-level assignment: the per-task ``lax.scan``.

    level[i] = max(base[i], 1 + max{ level[j] : C[i, j] }),  invalid -> -1

``base`` (default all-zero) is the cross-window carry-over floor: the
overlapped engines pass the carry frontier of the previous window there,
so a task cannot start before the tail waves it conflicts with have
drained (core/records.carry_frontier). With base = 0 this reduces to the
classic recurrence ``level[i] = 1 + max{level[j]}`` (else 0).

Robust to arbitrary (not necessarily lower-triangular) conflict matrices:
entries pointing at tasks not yet processed (j >= i) or at invalid tasks
contribute the initial level -1, i.e. nothing — the same convention the
blocked Pallas kernel reproduces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def wave_levels_ref(conflicts: jax.Array, valid: jax.Array,
                    base: jax.Array | None = None) -> jax.Array:
    """[W, W] bool-ish conflicts + [W] bool valid (+ optional [W] int32
    non-negative base floor) -> [W] int32 levels."""
    w = conflicts.shape[0]
    conflicts = conflicts.astype(bool)
    if base is None:
        base = jnp.zeros((w,), dtype=jnp.int32)
    base = base.astype(jnp.int32)

    def body(levels, i):
        row = conflicts[i]  # [W] bools over earlier tasks
        dep_levels = jnp.where(row, levels, -1)
        lvl = jnp.maximum(jnp.max(dep_levels, initial=-1) + 1, base[i])
        lvl = jnp.where(valid[i], lvl, -1)
        levels = levels.at[i].set(lvl)
        return levels, None

    levels0 = jnp.full((w,), -1, dtype=jnp.int32)
    levels, _ = jax.lax.scan(body, levels0, jnp.arange(w))
    return levels
