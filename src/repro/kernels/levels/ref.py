"""Pure-jnp oracle for wave-level assignment: the per-task ``lax.scan``.

    level[i] = 1 + max{ level[j] : C[i, j] }   (else 0),  invalid -> -1

Robust to arbitrary (not necessarily lower-triangular) conflict matrices:
entries pointing at tasks not yet processed (j >= i) or at invalid tasks
contribute the initial level -1, i.e. nothing — the same convention the
blocked Pallas kernel reproduces.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.jit
def wave_levels_ref(conflicts: jax.Array, valid: jax.Array) -> jax.Array:
    """[W, W] bool-ish conflicts + [W] bool valid -> [W] int32 levels."""
    w = conflicts.shape[0]
    conflicts = conflicts.astype(bool)

    def body(levels, i):
        row = conflicts[i]  # [W] bools over earlier tasks
        dep_levels = jnp.where(row, levels, -1)
        lvl = jnp.max(dep_levels, initial=-1) + 1
        lvl = jnp.where(valid[i], lvl, -1)
        levels = levels.at[i].set(lvl)
        return levels, None

    levels0 = jnp.full((w,), -1, dtype=jnp.int32)
    levels, _ = jax.lax.scan(body, levels0, jnp.arange(w))
    return levels
