"""Public wrapper for wave-level assignment.

Routes between the blocked Pallas kernel (compiled on TPU; interpreter
elsewhere) and the reference ``lax.scan``. On CPU the scan is the default:
Pallas interpret mode re-traces the block loop in Python, while XLA
compiles the scan into one tight loop. On TPU the blocked kernel replaces
W dependent scan steps with W/B sequential grid steps whose operands stay
in VMEM.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import ON_TPU
from repro.kernels.levels.levels import wave_levels_pallas
from repro.kernels.levels.ref import wave_levels_ref
from repro.obs.profiler import annotate


def wave_levels(conflicts, valid, *, base=None, backend: str | None = None,
                interpret: bool | None = None):
    """Wavefront levels [W] int32 from a prefix-conflict matrix.

        level[i] = max(base[i], 1 + max{ level[j] : j < i, C[i, j] })

    ``base`` (optional [W] int32, non-negative) is a per-task level
    floor — the overlapped engines pass the cross-window carry frontier
    (core/records.carry_frontier) so window k+1's tasks cannot start
    before the window-k tail waves they conflict with have drained; None
    (the default) means no floor, the classic recurrence (level 0 for
    tasks with no earlier conflicts). Invalid (padded) slots get level
    -1. Executing levels in ascending order is a topological order of
    the strict dependence DAG restricted to the window (paper §3.2).

    backend: None  — auto: Pallas (compiled) on TPU, the scan elsewhere;
             "pallas" — force the blocked kernel (interpret per
                        ``interpret`` arg, itself auto-detected when None);
             "jnp"    — force the scan reference.
    """
    conflicts = jnp.asarray(conflicts)
    valid = jnp.asarray(valid, bool)
    if base is not None:
        base = jnp.asarray(base, jnp.int32)
    if backend is None:
        backend = "pallas" if ON_TPU else "jnp"
    with annotate("protocol.wave_levels"):
        if backend == "jnp":
            return wave_levels_ref(conflicts, valid, base)
        if backend == "pallas":
            return wave_levels_pallas(conflicts, valid, base,
                                      interpret=interpret)
    raise ValueError(f"unknown levels backend {backend!r}")
