"""Blocked wave-level Pallas kernel.

``wave_levels`` is inherently a prefix recurrence — level[i] needs the
levels of every earlier conflicting task — which the reference implements
as a W-step ``lax.scan``: W dependent HBM round-trips, the last serial
stage on the scheduling path. The blocked formulation reduces the serial
structure to the B diagonal blocks of the (tiled) conflict matrix:

  grid step bi (sequential over W/B diagonal blocks):
    panel  = C[bi·B : bi·B+B, :]                      # [B, W] row panel
    dep0   = rowwise max of levels[j] over j < bi·B   # one vectorized
             where C[row, j]                          #   [B, W] pass
    in-block: a B-step loop resolves the [B, B] diagonal block, each step
             one vectorized masked max over the block
    levels[bi·B : bi·B+B] written; the full level vector stays resident
             in VMEM across grid steps (constant-index output block)

So the cross-block dependence work — all but a [B, B] sliver of the
matrix — is a single [B, W] vectorized pass per block instead of B scan
steps touching HBM, and the remaining serial loop runs on VMEM-resident
operands. Grid iteration on TPU is sequential by construction, which is
exactly the ordering the recurrence needs.

Semantics match the scan reference for *arbitrary* inputs: entries at or
above the diagonal and entries pointing at invalid tasks contribute the
initial level -1, i.e. nothing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128


def _kernel(conf_ref, valid_ref, base_ref, out_ref):
    bi = pl.program_id(0)
    b = conf_ref.shape[0]      # block rows
    wp = conf_ref.shape[1]     # padded window
    base = bi * b

    @pl.when(bi == 0)
    def _():
        out_ref[...] = jnp.full_like(out_ref, -1)

    panel = conf_ref[...] != 0                                   # [B, W]
    lv = out_ref[...].reshape(1, wp)                             # [1, W]
    col = jax.lax.broadcasted_iota(jnp.int32, (b, wp), 1)
    prior = jnp.where(panel & (col < base), lv, -1)
    dep0 = jnp.max(prior, axis=1, keepdims=True)                 # [B, 1]

    blk = conf_ref[:, pl.ds(base, b)] != 0                       # [B, B]
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    ri = jax.lax.broadcasted_iota(jnp.int32, (b, 1), 0)          # [B, 1]
    ci = jax.lax.broadcasted_iota(jnp.int32, (1, b), 1)          # [1, B]
    vrow = valid_ref[...] != 0                                   # [B, 1]
    floor = base_ref[...]                                        # [B, 1]

    def body(r, cur):
        # cur [1, B]: levels of the block's tasks resolved so far (-1 unset)
        m_in = jnp.max(jnp.where((rows == r) & blk, cur, -1))
        m_pre = jnp.max(jnp.where(ri == r, dep0, -1))
        base_r = jnp.max(jnp.where(ri == r, floor, 0))
        lvl = jnp.maximum(jnp.maximum(m_in, m_pre) + 1, base_r)
        valid_r = jnp.max(jnp.where((ri == r) & vrow, 1, 0)) > 0
        lvl = jnp.where(valid_r, lvl, -1)
        return jnp.where(ci == r, lvl, cur)

    cur = jax.lax.fori_loop(0, b, body,
                            jnp.full((1, b), -1, dtype=jnp.int32))
    out_ref[pl.ds(base, b), :] = cur.reshape(b, 1)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def wave_levels_pallas(conflicts, valid, base=None, *,
                       interpret: bool | None = None, block: int = BLOCK):
    """conflicts [W, W] bool/int, valid [W] bool -> [W] int32 levels.

    ``base`` (optional [W] int32, non-negative) is the per-task level
    floor — the overlapped engines' cross-window carry frontier; None
    means no floor (all-zero), the classic recurrence.

    interpret=None auto-detects the backend: compiled on TPU, Pallas
    interpreter elsewhere. Any window size is accepted; non-multiples of
    the tile size are padded with invalid slots internally.
    """
    if interpret is None:
        from repro.kernels import interpret_default

        interpret = interpret_default()
    w = conflicts.shape[0]
    b = min(block, w)
    wp = -(-w // b) * b  # next multiple of the tile size
    conf = conflicts.astype(jnp.int32)
    if base is None:
        base = jnp.zeros((w,), dtype=jnp.int32)
    base = base.astype(jnp.int32)
    if wp != w:
        conf = jnp.pad(conf, ((0, wp - w), (0, wp - w)))
        valid = jnp.pad(valid.astype(bool), (0, wp - w),
                        constant_values=False)
        base = jnp.pad(base, (0, wp - w))
    valid_i32 = valid.astype(jnp.int32)[:, None]  # [W, 1] for clean tiling
    base_i32 = base[:, None]                      # [W, 1] for clean tiling

    out = pl.pallas_call(
        _kernel,
        grid=(wp // b,),
        in_specs=[pl.BlockSpec((b, wp), lambda i: (i, 0)),
                  pl.BlockSpec((b, 1), lambda i: (i, 0)),
                  pl.BlockSpec((b, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((wp, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((wp, 1), jnp.int32),
        interpret=interpret,
    )(conf, valid_i32, base_i32)
    return out[:w, 0]
