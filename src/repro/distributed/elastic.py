"""Elastic rescaling: resume a checkpoint on a different mesh.

On a 1000+-node cluster, losing a pod mid-run must not lose the run. The
recovery path implemented here:

  1. the loop's CheckpointManager has a committed TrainState on stable
     storage (saved as logical, unsharded arrays),
  2. `rescale()` builds the new mesh from the surviving devices,
     recomputes sharding rules for the *new* mesh (the rules are pure
     functions of (path, shape, cfg, mesh) so any divisor-compatible mesh
     works), and device_puts each leaf with its new sharding,
  3. the caller re-jits the train step with the new shardings and resumes
     at the checkpointed step (data pipeline is step-indexed).

The same path handles scale-UP (new pod joins). Tested on CPU by reshaping
an 8-device host platform between (4, 2) and (2, 2) sub-meshes.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh

from repro.train.checkpoint import CheckpointManager
from repro.train.step import TrainState, train_state_shardings


def make_mesh_from_devices(devices, shape, axis_names) -> Mesh:
    devs = np.asarray(devices[: int(np.prod(shape))]).reshape(shape)
    return Mesh(devs, axis_names)


def rescale(ckpt: CheckpointManager, state_like: TrainState, cfg,
            new_mesh: Mesh, *, step: int | None = None):
    """Restore the latest committed TrainState onto `new_mesh`.

    Returns (state, shardings, step). `state_like` supplies the pytree
    structure and dtypes (e.g. from jax.eval_shape of init)."""
    shardings = train_state_shardings(state_like, cfg, new_mesh)
    state, at_step = ckpt.restore(state_like, step=step,
                                  shardings=shardings)
    return state, shardings, at_step
