from repro.distributed.sharding import (
    batch_pspec,
    param_pspec,
    params_shardings,
    states_shardings,
    zero1_pspec,
)

__all__ = [
    "param_pspec",
    "params_shardings",
    "batch_pspec",
    "states_shardings",
    "zero1_pspec",
]
