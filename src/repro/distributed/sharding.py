"""Logical-axis sharding rules for the production mesh.

Two mesh families live here:

  * the LM training/serving mesh — ("pod", "data", "model") multi-pod or
    ("data", "model") single pod (policy below), and
  * the MABS agent mesh — a 1-D ("agents",) mesh for the sharded
    wavefront engine (repro.engine.sharded): agent-state leaves lead
    with the agent axis and shard into contiguous row blocks; window
    -local scheduling objects stay replicated (docs/engine.md).

LM policy (DESIGN.md §8):

  * batch                      -> (pod, data)          [DP]
  * attention heads / kv heads -> model                [TP] when divisible
  * MLP hidden, vocab          -> model                [TP] when divisible
  * experts                    -> model                [EP]
  * optimizer moments          -> param spec + data axis on the largest
                                  still-replicated dim [ZeRO-1]

Head-structured weights are stored flattened ([D, H·hd]); sharding them
only makes sense on whole-head boundaries, so the rules consult the config
(n_heads % model_size) rather than the raw dim size. Anything that does not
divide cleanly is replicated — divergences show up in the roofline table
rather than as GSPMD surprises.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.obs.profiler import annotate
from repro.utils.pytree import tree_map_with_path_str


# --------------------------------------------------------------------------
# MABS agent mesh (repro.engine.sharded)

AGENT_AXIS = "agents"


def agents_mesh(devices=None) -> Mesh:
    """1-D mesh over the agent axis for the sharded wavefront engine."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), (AGENT_AXIS,))


def agent_pspec(ndim: int) -> P:
    """Leading-axis (agent) sharding; trailing dims replicated."""
    return P(AGENT_AXIS, *([None] * (ndim - 1)))


def agent_state_shardings(state: Any, mesh: Mesh):
    """NamedShardings for an agent-state pytree (every leaf leads with
    the agent axis — the sharded engine's state contract)."""
    return jax.tree_util.tree_map(
        lambda x: NamedSharding(mesh, agent_pspec(x.ndim)), state)


# ---- halo exchange (repro.engine.sharded, halo mode) ----------------------
#
# The sharded engine's communication-sparse mode. A window's tasks read a
# degree-bounded set of agent rows (the models' task_read_agents /
# task_write_agents contracts); instead of all-gathering the full O(N)
# state every wave, the schedule carries the flattened row list and each
# wave ships exactly those rows: every row has a unique owner shard, the
# owner contributes its value, a psum over the agent axis delivers the
# row to all devices. Per-wave comm is O(halo · trailing) values per
# device versus the all_gather's O(N · trailing).

def window_halo(read_agents: jax.Array, write_agents: jax.Array) -> jax.Array:
    """Flatten a window's read ∪ write state rows into the gather list.

    read_agents [W, nr] / write_agents [W, nw] int32, -1 padded; returns
    [W·(nr+nw)] int32 with -1 marking unused slots. Static width — the
    halo is degree-bounded by construction (nr tracks max_degree), and
    duplicates are kept: the refresh scatter is idempotent, so dedup
    would only shuffle bytes without shrinking the static buffer.
    Computed at schedule time on replicated values, so every device
    derives the identical list without communicating.
    """
    return jnp.concatenate(
        [read_agents.reshape(-1), write_agents.reshape(-1)]
    ).astype(jnp.int32)


def pair_halo(halo_prev: jax.Array, halo_next: jax.Array) -> jax.Array:
    """Halo for an overlapped window pair: the union of both windows'
    read ∪ write rows, realized by concatenation — [h_prev + h_next]
    int32, -1 slots preserved. During cross-window overlap a fused wave
    may execute window k tail tasks *and* window k+1 head tasks, so the
    per-wave gather must deliver every row either side can touch.
    Duplicates across the two windows are kept for the same reason
    ``window_halo`` keeps them: the refresh scatter is idempotent and the
    static width is what shard_map needs. Like ``window_halo``, computed
    at schedule time on replicated values — no communication.
    """
    return jnp.concatenate([halo_prev, halo_next]).astype(jnp.int32)


def halo_gather(local: jax.Array, halo: jax.Array, *, shard_n: int,
                axis: str = AGENT_AXIS) -> jax.Array:
    """Inside shard_map on the agents mesh: gather global rows ``halo``
    from a row-sharded array.

    local [shard_n, ...] is this device's contiguous row block; halo [h]
    holds global row ids (-1 = unused, gathers zeros). Each real row has
    exactly one owner (id // shard_n), so masking non-owned slots to zero
    and psum-ing over the axis reconstructs the rows everywhere — one
    all-reduce of h rows instead of an all_gather of N. A zero-width halo
    (a fully-drained wave's slab in overlapped mode) is a clean no-op: no
    collective is issued rather than a degenerate 0-row psum.
    """
    if halo.shape[0] == 0:
        return jnp.zeros((0,) + local.shape[1:], local.dtype)
    with annotate("protocol.halo_gather"):
        dev = jax.lax.axis_index(axis)
        owner = jnp.where(halo >= 0, halo // shard_n, -1)
        idx = jnp.clip(halo - dev * shard_n, 0, shard_n - 1)
        rows = jnp.take(local, idx, axis=0)
        sel = (owner == dev).reshape((-1,) + (1,) * (rows.ndim - 1))
        return jax.lax.psum(jnp.where(sel, rows, 0), axis)


def halo_scatter(full: jax.Array, halo: jax.Array,
                 gathered: jax.Array) -> jax.Array:
    """Refresh rows ``halo`` of a full-size buffer with gathered values
    (-1 slots dropped; duplicate slots write identical values)."""
    with annotate("protocol.halo_scatter"):
        rows = jnp.where(halo >= 0, halo, full.shape[0])
        return full.at[rows].set(gathered, mode="drop")


# ---- per-wave halo splitting (schedule-time comm specialization) ----------
#
# The window halo above is monolithic: every wave re-gathers the whole
# window's read ∪ write rows, O(W·slots) per wave however little wave w
# actually touches. But wave levels are known at schedule time, so the
# halo can be split into per-wave slabs: wave w gathers only the rows of
# tasks at level w. Per-wave slab widths are heavily skewed (level 0
# usually holds most of a window's tasks, tail waves a handful), so a
# rectangular [n_waves, rows_per_wave_max] padding would be dominated by
# wave 0 and win nothing; instead the slabs are laid out *wave-major in
# fixed-size chunks* — wave w owns the chunk range
# [chunk_start[w], chunk_start[w+1]), each chunk a static-width gather —
# and the executor issues a dynamic number of chunk gathers per wave.
# Shipped volume per wave is ceil(rows_w / chunk)·chunk ≈ rows_w, summed
# over the window ≈ one window halo instead of n_waves of them. Every
# shape is static, so the layout builds inside the jitted schedule and
# no host sync or per-window recompilation is ever needed; ``chunk``
# trades collective count (latency) against padding waste (bandwidth).

def wave_slab_counts(rows: jax.Array, levels: jax.Array, *,
                     n_waves_max: int) -> jax.Array:
    """Valid-row count of each wave's slab.

    rows [W, slots] int32 per-task read ∪ write state rows (-1 padded);
    levels [W] int32 wave level per task (-1 = invalid/executed). Returns
    [n_waves_max] int32. Unlike ``window_halo``, -1 row slots are dropped
    — the slab layout is allowed to be tighter than the static halo.
    """
    slots = rows.shape[1]
    wave = jnp.repeat(jnp.asarray(levels, jnp.int32), slots)
    ok = (rows.reshape(-1) >= 0) & (wave >= 0) & (wave < n_waves_max)
    key = jnp.where(ok, wave, n_waves_max)
    return jax.ops.segment_sum(ok.astype(jnp.int32), key,
                               num_segments=n_waves_max + 1)[:n_waves_max]


def wave_halo_split(rows: jax.Array, levels: jax.Array, *,
                    n_waves_max: int, chunk: int,
                    n_chunks_max: int | None = None):
    """Partition a window's read ∪ write rows into per-wave chunked slabs.

    rows [W, slots] int32 (-1 padded), levels [W] int32 (-1 dropped —
    executed tasks of a draining window contribute nothing). Returns

      slabs       [n_chunks_max, chunk] int32, -1 padded: wave-major
                  chunk layout; wave w's rows fill chunks
                  [chunk_start[w], chunk_start[w+1]) contiguously,
      chunk_start [n_waves_max + 1] int32 cumulative chunk offsets
                  (an empty wave owns zero chunks -> a clean no-op).

    ``n_chunks_max`` defaults to the worst case
    ceil(W·slots / chunk) + n_waves_max (every wave pays at most one
    partially-filled chunk); rows whose wave is >= n_waves_max are
    dropped (an overlapped pair's next-window tasks beyond the drain
    horizon — they are re-split after rebasing). Pure jnp with static
    shapes: runs inside the jitted schedule on replicated values, so
    every device derives the identical layout without communicating.
    """
    w_tasks, slots = rows.shape
    if n_chunks_max is None:
        n_chunks_max = -(-(w_tasks * slots) // chunk) + n_waves_max
    return _wave_halo_split(rows, levels, n_waves_max=n_waves_max,
                            chunk=chunk, n_chunks_max=n_chunks_max)


@annotate("protocol.wave_halo_split")
def _wave_halo_split(rows, levels, *, n_waves_max, chunk, n_chunks_max):
    slots = rows.shape[1]
    flat = rows.reshape(-1)
    wave = jnp.repeat(jnp.asarray(levels, jnp.int32), slots)
    ok = (flat >= 0) & (wave >= 0) & (wave < n_waves_max)
    key = jnp.where(ok, wave, n_waves_max)
    counts = wave_slab_counts(rows, levels, n_waves_max=n_waves_max)
    n_chunks = -(-counts // chunk)
    chunk_start = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                                   jnp.cumsum(n_chunks).astype(jnp.int32)])
    # rank of each kept entry within its wave: stable sort groups waves
    # contiguously (sentinel n_waves_max sinks dropped entries past the
    # real segments), rank = sorted position - segment start
    order = jnp.argsort(key, stable=True)
    k_sorted, r_sorted = key[order], flat[order]
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts).astype(jnp.int32)])
    rank = (jnp.arange(k_sorted.shape[0], dtype=jnp.int32)
            - starts[jnp.minimum(k_sorted, n_waves_max)])
    # flat position in the chunked layout: wave w's chunk range, row rank
    pos = chunk_start[jnp.minimum(k_sorted, n_waves_max)] * chunk + rank
    keep = (k_sorted < n_waves_max) & (pos < n_chunks_max * chunk)
    slabs = jnp.full((n_chunks_max * chunk,), -1, jnp.int32)
    slabs = slabs.at[jnp.where(keep, pos, n_chunks_max * chunk)].set(
        r_sorted, mode="drop")
    return slabs.reshape(n_chunks_max, chunk), chunk_start


def wave_halo_gather(local: jax.Array, slabs: jax.Array, c: jax.Array, *,
                     shard_n: int, axis: str = AGENT_AXIS):
    """Gather chunk ``c`` of a per-wave slab layout from a row-sharded
    array: returns (rows [chunk, ...], slab [chunk]) — the slab is handed
    back so the caller can scatter the gathered rows without re-indexing.
    Zero-width chunks (slabs built with chunk=0) no-op without issuing a
    collective, matching ``halo_gather``.
    """
    with annotate("protocol.wave_halo_gather"):
        slab = jax.lax.dynamic_index_in_dim(slabs, c, axis=0, keepdims=False)
        return halo_gather(local, slab, shard_n=shard_n, axis=axis), slab


# --------------------------------------------------------------------------
# LM training/serving mesh


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_size(mesh: Mesh) -> int:
    return int(np.prod([_axis_size(mesh, a) for a in data_axes(mesh)]))


def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def param_pspec(path: str, leaf, cfg, mesh: Mesh) -> P:
    """PartitionSpec for one parameter leaf (path: '/'-joined names;
    stacked-layer leading dims are auto-detected from rank)."""
    model = _axis_size(mesh, "model")
    shape = leaf.shape

    def heads_ok(n):
        return _divisible(n, model)

    if getattr(cfg, "layout", "tp") == "dp":
        # pure-DP layout: params replicated; the model axis carries extra
        # batch shards instead of TP (§Perf — rwkv6 hillclimb)
        return P(*([None] * len(shape)))

    spec: list = [None] * len(shape)

    def set_last(ax):
        spec[-1] = ax

    def set_first_matrix_dim(ax):
        # first *matrix* dim = -2 for rank>=2 leaves
        if len(shape) >= 2:
            spec[-2] = ax

    if re.search(r"embed/table$", path):
        if _divisible(cfg.vocab, model):
            spec[-2] = "model"                      # vocab-parallel rows
    elif re.search(r"lm_head/w$", path):
        if _divisible(cfg.vocab, model):
            set_last("model")
    elif re.search(r"experts/(w_gate|w_up)$", path):
        # [.., E, D, Fe] — 2-D expert sharding: experts over the data axes
        # (FSDP-style ownership; grads reduce-scatter automatically). This
        # is what lets 480B-class MoEs fit 16 GiB chips (DESIGN.md §8).
        # The TP dim differs per impl: shard_map contracts over D (ships
        # D-slices through the a2a), dense shards the expert hidden Fe.
        daxes = data_axes(mesh)
        if daxes and _divisible(cfg.moe.n_experts, data_size(mesh)):
            spec[-3] = daxes if len(daxes) > 1 else daxes[0]
        elif _divisible(cfg.moe.n_experts, model):
            spec[-3] = "model"
        if spec[-3] != "model":
            if getattr(cfg, "moe_impl", "dense").startswith("shard_map"):
                if _divisible(cfg.d_model, model):
                    spec[-2] = "model"
            elif _divisible(cfg.moe.d_expert, model):
                spec[-1] = "model"
    elif re.search(r"experts/w_out$", path):
        daxes = data_axes(mesh)
        if daxes and _divisible(cfg.moe.n_experts, data_size(mesh)):
            spec[-3] = daxes if len(daxes) > 1 else daxes[0]
        elif _divisible(cfg.moe.n_experts, model):
            spec[-3] = "model"
        if spec[-3] != "model":
            if getattr(cfg, "moe_impl", "dense").startswith("shard_map"):
                if _divisible(cfg.d_model, model):
                    spec[-1] = "model"
            elif _divisible(cfg.moe.d_expert, model):
                spec[-2] = "model"
    elif re.search(r"(attn|xattn)/wq/w$", path):
        if heads_ok(cfg.n_heads):
            set_last("model")
    elif re.search(r"(attn|xattn)/w[kv]/w$", path):
        if heads_ok(cfg.n_kv_heads):
            set_last("model")
    elif re.search(r"(attn|xattn)/wo/w$", path):
        if heads_ok(cfg.n_heads):
            set_first_matrix_dim("model")
    elif re.search(r"(attn|xattn)/wq/b$", path):
        if heads_ok(cfg.n_heads):
            set_last("model")
    elif re.search(r"(attn|xattn)/w[kv]/b$", path):
        if heads_ok(cfg.n_kv_heads):
            set_last("model")
    elif re.search(r"(mlp|dense_mlp)/(w_gate|w_up)/w$", path):
        if _divisible(cfg.d_ff, model):
            set_last("model")
    elif re.search(r"(mlp|dense_mlp)/w_out/w$", path):
        if _divisible(cfg.d_ff, model):
            set_first_matrix_dim("model")
    elif re.search(r"rwkv/tm/w[rkvg]/w$", path):
        if _divisible(cfg.d_model, model) and heads_ok(
                cfg.d_model // cfg.hd):
            set_last("model")
    elif re.search(r"rwkv/tm/wo/w$", path):
        if _divisible(cfg.d_model, model) and heads_ok(
                cfg.d_model // cfg.hd):
            set_first_matrix_dim("model")
    elif re.search(r"rwkv/cm/wk/w$", path):
        if _divisible(cfg.d_ff, model):
            set_last("model")
    elif re.search(r"rwkv/cm/wv/w$", path):
        if _divisible(cfg.d_ff, model):
            set_first_matrix_dim("model")
    elif re.search(r"ssm/(w_x|w_z|w_b|w_c|w_dt)/w$", path) and cfg.ssm:
        nh = cfg.ssm.n_heads or cfg.d_model // cfg.ssm.head_dim
        if heads_ok(nh):
            set_last("model")
    elif re.search(r"ssm/w_out/w$", path) and cfg.ssm:
        nh = cfg.ssm.n_heads or cfg.d_model // cfg.ssm.head_dim
        if heads_ok(nh):
            set_first_matrix_dim("model")
    # everything else (norms, mus, router, biases, prefix): replicated
    return P(*spec)


def params_shardings(params_shapes: Any, cfg, mesh: Mesh):
    """Pytree of NamedSharding matching a pytree of arrays/SDS."""
    return tree_map_with_path_str(
        lambda path, leaf: NamedSharding(
            mesh, param_pspec(path, leaf, cfg, mesh)),
        params_shapes)


def zero1_pspec(path: str, leaf, cfg, mesh: Mesh) -> P:
    """Optimizer-moment spec: the param spec plus 'data' on the largest
    still-unsharded, divisible dim (ZeRO-1 state partitioning)."""
    base = param_pspec(path, leaf, cfg, mesh)
    spec = list(base) + [None] * (len(leaf.shape) - len(base))
    daxes = data_axes(mesh)
    if getattr(cfg, "layout", "tp") == "dp" and "model" in mesh.axis_names:
        daxes = daxes + ("model",)   # ZeRO over every axis in pure-DP
    dsize = int(np.prod([_axis_size(mesh, a) for a in daxes])) if daxes else 1
    if dsize <= 1 or not daxes:
        return P(*spec)
    # already consuming a data axis (e.g. 2-D-sharded experts)? done.
    used = set()
    for s in spec:
        for a in (s if isinstance(s, tuple) else (s,)):
            used.add(a)
    if any(a in used for a in daxes):
        return P(*spec)
    # pick the largest unsharded dim divisible by the data size
    cand = [(dim, i) for i, dim in enumerate(leaf.shape)
            if spec[i] is None and dim % dsize == 0]
    if cand:
        _, i = max(cand)
        spec[i] = daxes if len(daxes) > 1 else daxes[0]
    return P(*spec)


def opt_state_shardings(params_shapes: Any, cfg, mesh: Mesh):
    return tree_map_with_path_str(
        lambda path, leaf: NamedSharding(
            mesh, zero1_pspec(path, leaf, cfg, mesh)),
        params_shapes)


def batch_pspec(mesh: Mesh, leaf_shape, *, batch_size: int,
                layout: str = "tp") -> P:
    """Batch inputs: leading dim over (pod, data); the "dp" layout also
    folds the model axis into the batch (pure data parallelism)."""
    candidates = [data_axes(mesh)]
    if layout == "dp" and "model" in mesh.axis_names:
        candidates.insert(0, data_axes(mesh) + ("model",))
    for daxes in candidates:
        dsize = (int(np.prod([_axis_size(mesh, a) for a in daxes]))
                 if daxes else 1)
        if daxes and batch_size % dsize == 0:
            first = daxes if len(daxes) > 1 else daxes[0]
            return P(first, *([None] * (len(leaf_shape) - 1)))
    return P(*([None] * len(leaf_shape)))


def batch_shardings(batch_specs: Any, mesh: Mesh, *, layout: str = "tp"):
    def f(leaf):
        b = leaf.shape[0] if leaf.shape else 1
        return NamedSharding(mesh, batch_pspec(mesh, leaf.shape,
                                               batch_size=b, layout=layout))

    return jax.tree_util.tree_map(f, batch_specs)


def states_shardings(states_shapes: Any, cfg, mesh: Mesh, *,
                     global_batch: int):
    """Decode/serving state shardings: KV caches [L, B, Hkv, S, hd] get
    batch->data and kv_heads->model (whole heads only); SSM states
    [L, B, H, P, N] get batch->data, heads->model; scalars replicated."""
    model = _axis_size(mesh, "model")
    daxes = data_axes(mesh)
    dsize = data_size(mesh)
    batch_ax = (daxes if len(daxes) > 1 else daxes[0]) if daxes else None
    shard_batch = batch_ax is not None and global_batch % dsize == 0

    def f(path: str, leaf):
        spec: list = [None] * len(leaf.shape)
        if re.search(r"kv/(k|v)$", path) and len(leaf.shape) == 5:
            if shard_batch:
                spec[1] = batch_ax
            if _divisible(cfg.n_kv_heads, model):
                spec[2] = "model"
            elif getattr(cfg, "seq_shard_cache", False) \
                    and _divisible(leaf.shape[3], model):
                # flash-decode style: when kv heads can't split, shard the
                # sequence dim; softmax partials combine via small psums
                spec[3] = "model"
        elif re.search(r"kv/(kpos|length)$", path):
            if shard_batch and len(leaf.shape) >= 2:
                spec[1] = batch_ax
        elif path == "pos" and len(leaf.shape) == 1:
            if shard_batch:
                spec[0] = batch_ax
        elif re.search(r"/ssm$", path) and len(leaf.shape) == 5:
            nh = cfg.ssm.n_heads or cfg.d_model // cfg.ssm.head_dim
            if shard_batch:
                spec[1] = batch_ax
            if _divisible(nh, model):
                spec[2] = "model"
        elif re.search(r"tm/s$", path) and len(leaf.shape) == 5:
            nh = cfg.d_model // cfg.hd
            if shard_batch:
                spec[1] = batch_ax
            if _divisible(nh, model):
                spec[2] = "model"
        elif re.search(r"(tm|cm)/last$", path) and len(leaf.shape) == 4:
            if shard_batch:
                spec[1] = batch_ax
        elif re.search(r"enc_out$", path) and len(leaf.shape) == 3:
            if shard_batch:
                spec[0] = batch_ax
        return NamedSharding(mesh, P(*spec))

    return tree_map_with_path_str(f, states_shapes)
