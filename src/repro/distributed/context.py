"""Ambient mesh context — lets model code (shard_map layers) find the mesh
the launcher built without threading it through every config."""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

from jax.sharding import Mesh

_MESH: Optional[Mesh] = None


def set_mesh(mesh: Optional[Mesh]) -> None:
    global _MESH
    _MESH = mesh


def get_mesh() -> Optional[Mesh]:
    return _MESH


@contextmanager
def mesh_context(mesh: Mesh):
    prev = get_mesh()
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(prev)
