"""Error-feedback int8 gradient compression for cross-pod data parallelism.

Beyond-paper distributed-optimization trick (DESIGN.md §8): the pod axis
crosses the slow inter-pod links, so gradients reduced across pods are
quantized to int8 with per-leaf scale and an error-feedback residual that
re-injects the quantization error into the next step (Seide et al. 2014 /
1-bit Adam lineage; error feedback keeps SGD convergence guarantees).

The compressed collective is expressed shard_map-natively:
    psum(dequant(quant(g)))  over the 'pod' axis
so XLA ships int8 (4x fewer bytes) across the inter-pod links and the
all-reduce epilogue upcasts locally. Within a pod (fast ICI) gradients
stay bf16/f32.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: Any     # pytree like grads (f32)


def ef_init(grads_like) -> EFState:
    return EFState(residual=jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_leaf(g, r):
    """Error-feedback compression of one gradient leaf.

    Returns (g_compressed_f32, new_residual). The caller reduces
    g_compressed across the pod axis; the residual stays local.
    """
    gf = g.astype(jnp.float32) + r
    q, scale = quantize_int8(gf)
    deq = dequantize_int8(q, scale)
    return deq.astype(g.dtype), gf - deq


def compress_grads(grads, ef: EFState) -> tuple[Any, EFState]:
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_r = tdef.flatten_up_to(ef.residual)
    outs = [compress_leaf(g, r) for g, r in zip(flat_g, flat_r)]
    return (tdef.unflatten([o[0] for o in outs]),
            EFState(tdef.unflatten([o[1] for o in outs])))


def crosspod_allreduce_compressed(grads, ef: EFState, *, axis: str = "pod"):
    """Inside shard_map over the pod axis: compress, psum, average."""
    cg, ef = compress_grads(grads, ef)
    n = jax.lax.psum(1, axis)
    reduced = jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g, axis) / n, cg)
    return reduced, ef
