"""Architecture configuration dataclasses.

One ``ArchConfig`` instance per assigned architecture (configs/<id>.py),
plus ``reduced()`` variants used by the CPU smoke tests. All fields mirror
the public configs cited in the assignment; anything we had to interpret is
commented at the use site.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int                 # expert FFN hidden size
    dense_parallel: bool = False  # Arctic: dense FFN residual in parallel
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3


@dataclass(frozen=True)
class SSMSpec:
    state_dim: int = 16           # per-head SSM state (hymba)
    n_heads: int = 0              # 0 -> derive from d_model / head_dim
    head_dim: int = 64
    chunk: int = 256              # chunked-scan length


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | ssm | moe | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None          # default: d_model // n_heads
    qkv_bias: bool = False                  # qwen1.5
    sliding_window: Optional[int] = None    # SWA width (danube, hymba local)
    global_layers: Tuple[int, ...] = ()     # hymba: full-attention layers
    moe: Optional[MoESpec] = None
    ssm: Optional[SSMSpec] = None
    enc_layers: int = 0                     # seamless: encoder depth
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    frontend: Optional[str] = None          # "audio_stub" | "vision_stub"
    frontend_len: int = 0                   # stub prefix length (patches/frames)
    n_prefix_tokens: int = 0                # hymba meta tokens
    param_dtype: str = "bfloat16"
    # execution knobs (not architecture): overridable per run
    attn_impl: str = "chunked"              # ref | chunked | pallas
    attn_chunk: int = 256
    remat: bool = True
    use_scan: bool = True
    gqa_expand: bool = False                # expand KV to H heads before
    # attention so TP can shard H when Hkv doesn't divide the model axis
    # (set by the launcher from the mesh; train/prefill paths only)
    moe_impl: str = "dense"                 # dense | shard_map (§Perf)
    layout: str = "tp"                      # tp | dp — "dp" folds the model
    # axis into data parallelism (replicated params, ZeRO over all axes);
    # wins for small attention-free models whose heads don't divide the
    # model axis (rwkv6: measured §Perf)
    seq_shard_cache: bool = False           # decode KV cache: shard the seq
    # dim over model when kv_heads don't divide it (flash-decode style)
    tp_shard_map: bool = False              # manual Megatron-SP block via
    # shard_map (models/block_sharded.py); train path, dense/vlm kinds,
    # requires n_heads % model == 0
    seq_parallel: bool = False              # Megatron-SP: residual stream
    # sequence-sharded over model between blocks; GSPMD turns the per-layer
    # all-reduces into reduce-scatter + all-gather pairs (≈2× less wire)
    kv_cache_dtype: str = "bfloat16"        # bfloat16 | float8_e4m3fn —
    # fp8 KV halves decode cache memory/bandwidth (upcast on read)

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state, hybrid, or sliding-window KV."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def n_params(self) -> int:
        """Analytic parameter count (matches init to within ties/norms)."""
        d, f, v, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        hq, hkv, hd = self.n_heads, self.n_kv_heads, self.hd
        attn = d * hq * hd + 2 * d * hkv * hd + hq * hd * d
        if self.qkv_bias:
            attn += hq * hd + 2 * hkv * hd
        if self.family == "ssm":
            # rwkv6: time-mix (r,k,v,g,o: 5 d² + decay lora) + channel-mix
            attn = 5 * d * d + d * 96 + 96 * d
            mlp = 2 * d * f
        elif self.moe is not None:
            e = self.moe
            mlp = e.n_experts * 3 * d * e.d_expert + d * e.n_experts
            if e.dense_parallel:
                mlp += 3 * d * f
        else:
            mlp = 3 * d * f
        if self.family == "hybrid" and self.ssm is not None:
            nh = self.ssm.n_heads or d // self.ssm.head_dim
            p = self.ssm.head_dim
            # in-proj (x, z, B, C, dt) + out-proj
            attn += d * (2 * nh * p + 2 * nh * self.ssm.state_dim + nh) \
                + nh * p * d
        layers = L * (attn + mlp)
        if self.is_encdec:
            # decoder adds cross-attention per layer
            layers += self.n_layers * attn  # cross-attn in decoder layers
            layers += self.enc_layers * (attn + mlp)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return layers + emb

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.n_params()
        e = self.moe
        total = self.n_params()
        expert_params = self.n_layers * e.n_experts * 3 * self.d_model * e.d_expert
        active = self.n_layers * e.top_k * 3 * self.d_model * e.d_expert
        return total - expert_params + active

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
            enc_layers=2 if self.is_encdec else 0,
            frontend_len=16 if self.frontend else 0,
            n_prefix_tokens=8 if self.n_prefix_tokens else 0,
            sliding_window=64 if self.sliding_window else None,
            global_layers=(0,) if self.global_layers else (),
            param_dtype="float32",
            attn_impl="ref",
            attn_chunk=64,
            use_scan=True,
        )
        if self.moe is not None:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2),
                d_expert=64)
        if self.ssm is not None:
            changes["ssm"] = dataclasses.replace(
                self.ssm, state_dim=8, head_dim=32, n_heads=4, chunk=32)
        return dataclasses.replace(self, **changes)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)
