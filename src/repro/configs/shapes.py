"""Assigned input shapes (per-arch cells of the dry-run matrix).

  train_4k     seq 4096,   global batch 256  -> train_step
  prefill_32k  seq 32768,  global batch 32   -> serve_prefill
  decode_32k   KV len 32768, batch 128       -> serve_step (1 new token)
  long_500k    KV len 524288, batch 1        -> serve_step; sub-quadratic
               archs only (ssm / hybrid / sliding-window)
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-not). 40 cells total; skips are documented in
    DESIGN.md §6 and EXPERIMENTS.md §Dry-run."""
    if shape.name == "long_500k" and not cfg.supports_long_context():
        return False, (
            "pure full-attention architecture: 500k decode requires "
            "sub-quadratic attention (unbounded KV cache does not fit)")
    return True, ""


def reduced_shape(shape: ShapeSpec) -> ShapeSpec:
    """Tiny variant of a shape for CPU smoke tests."""
    return ShapeSpec(shape.name, shape.kind,
                     seq_len=min(shape.seq_len, 128),
                     global_batch=min(shape.global_batch, 2))
