"""seamless-m4t-medium [audio] — encoder-decoder, multimodal frontend STUB.

12L enc + 12L dec, d_model=1024 16H (MHA) d_ff=4096 vocab=256206
[arXiv:2308.11596]. The speech frontend is a stub per the assignment:
input_specs supplies precomputed frame embeddings [B, T, D].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    frontend="audio_stub",
)
