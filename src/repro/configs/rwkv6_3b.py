"""rwkv6-3b [ssm] — Finch, attention-free with data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536  [arXiv:2404.05892]
Internal WKV heads: head_dim 64 -> 40 heads. n_heads/n_kv_heads are unused
by the rwkv block but kept consistent for tooling.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=8960,
    vocab=65536,
    head_dim=64,
)
