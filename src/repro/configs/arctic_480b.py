"""arctic-480b [moe] — Snowflake Arctic: dense FFN residual *in parallel*
with a 128-expert top-2 MoE.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2
[hf:Snowflake/snowflake-arctic-base]  head_dim = 7168/56 = 128.
"""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    moe=MoESpec(n_experts=128, top_k=2, d_expert=4864, dense_parallel=True),
)
