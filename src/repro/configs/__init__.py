from repro.configs.base import ArchConfig, MoESpec, SSMSpec
from repro.configs.registry import ARCHS, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, applicable, reduced_shape

__all__ = ["ArchConfig", "MoESpec", "SSMSpec", "ARCHS", "get_config",
           "SHAPES", "ShapeSpec", "applicable", "reduced_shape"]
