"""internvl2-76b [vlm] — InternViT frontend STUB + LLaMA-3-70B-class
language backbone (the assignment specifies the backbone only).

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256 [arXiv:2404.16821]
input_specs supplies precomputed patch embeddings [B, P, D] prepended to
the text tokens.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    frontend="vision_stub",
)
