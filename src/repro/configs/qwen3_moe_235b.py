"""qwen3-moe-235b-a22b [moe] — 128 experts, top-8, no dense MLP.

94L d_model=4096 64H (GQA kv=4) expert d_ff=1536 vocab=151936
[hf:Qwen/Qwen3-235B-A22B]  head_dim 128 (decoupled from d_model/n_heads).
"""
from repro.configs.base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_ff=1536,
    vocab=151936,
    head_dim=128,
    rope_theta=1_000_000.0,
    moe=MoESpec(n_experts=128, top_k=8, d_expert=1536),
)
