"""hymba-1.5b [hybrid] — parallel attention + Mamba heads per layer.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676]. SWA (window 1024) everywhere except 3 full-attention
layers (first / middle / last); 128 learned meta tokens prepended.
"""
from repro.configs.base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab=32001,
    head_dim=64,
    sliding_window=1024,
    global_layers=(0, 16, 31),
    n_prefix_tokens=128,
    ssm=SSMSpec(state_dim=16, n_heads=25, head_dim=64),
)
