"""Architecture registry: --arch <id> resolution."""
from __future__ import annotations

from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.base import ArchConfig
from repro.configs.deepseek_7b import CONFIG as _deepseek
from repro.configs.h2o_danube3_4b import CONFIG as _danube
from repro.configs.hymba_1p5b import CONFIG as _hymba
from repro.configs.internvl2_76b import CONFIG as _internvl
from repro.configs.qwen15_32b import CONFIG as _qwen15
from repro.configs.qwen3_moe_235b import CONFIG as _qwen3
from repro.configs.rwkv6_3b import CONFIG as _rwkv6
from repro.configs.seamless_m4t_medium import CONFIG as _seamless
from repro.configs.smollm_360m import CONFIG as _smollm

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        _danube, _smollm, _qwen15, _deepseek, _rwkv6,
        _seamless, _arctic, _qwen3, _hymba, _internvl,
    ]
}


def get_config(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(
            f"unknown arch '{name}'; available: {sorted(ARCHS)}")
    return ARCHS[name]
