"""Padded-CSR contact topology — the substrate for localized dynamics.

The paper's protocol only assumes updates are *localized*; the structure of
the contact network is what determines how much parallelism the record check
exposes (cf. Fachada et al. on spatial decomposition). ``Topology`` is the
repo-wide representation of that network: a fixed-width neighbor table

    neighbors : [n_nodes, max_degree] int32, row v lists v's neighbors,
                padded with -1 past degrees[v]
    degrees   : [n_nodes] int32

which is the SPMD-friendly dual of a CSR adjacency — every gather is a
rectangular ``neighbors[v]`` with a static trailing dim, so model code can
vmap/jit over it freely. The -1 padding convention matches the conflict
kernel's "unused id slot" convention, letting ``neighbors[v]`` be dropped
directly into a task's read-id footprint.

Registered as a pytree so a Topology can be closed over by jitted functions
or passed through them as an argument.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

PAD = -1  # unused neighbor slot; also "unused id" in the conflict kernel


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Topology:
    """Undirected contact graph in padded neighbor-table form."""

    neighbors: jax.Array  # [n_nodes, max_degree] int32, -1 padded
    degrees: jax.Array    # [n_nodes] int32

    def tree_flatten(self):
        return (self.neighbors, self.degrees), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # ---------------------------------------------------------- properties
    @property
    def n_nodes(self) -> int:
        return self.neighbors.shape[0]

    @property
    def max_degree(self) -> int:
        return self.neighbors.shape[1]

    @property
    def n_edges(self) -> jax.Array:
        """Undirected edge count. A proper edge appears in two rows, a
        self-loop (block graphs have them) in one."""
        n = self.neighbors.shape[0]
        loops = jnp.sum(jnp.any(
            self.neighbors == jnp.arange(n, dtype=jnp.int32)[:, None],
            axis=1))
        return (jnp.sum(self.degrees) + loops) // 2

    # ------------------------------------------------------------- queries
    def neighbor_mask(self) -> jax.Array:
        """[n_nodes, max_degree] bool — True where a slot holds a neighbor."""
        return self.neighbors >= 0

    def gather(self, values: jax.Array, rows: jax.Array,
               fill=0) -> tuple[jax.Array, jax.Array]:
        """values[neighbors[rows]] with padded slots replaced by ``fill``.

        rows may have any leading shape; returns (gathered, mask) with shape
        rows.shape + (max_degree,) (+ values' trailing dims).
        """
        nbrs = self.neighbors[rows]
        mask = nbrs >= 0
        safe = jnp.where(mask, nbrs, 0)
        out = values[safe]
        bshape = mask.shape + (1,) * (out.ndim - mask.ndim)
        return jnp.where(mask.reshape(bshape), out, fill), mask

    def neighbor_fraction(self, indicator: jax.Array,
                          rows: jax.Array) -> jax.Array:
        """Mean of a boolean per-node indicator over each row's neighbors
        (0 where degree is 0) — e.g. the infected fraction in epidemics."""
        vals, _ = self.gather(indicator.astype(jnp.float32), rows, fill=0.0)
        deg = jnp.maximum(self.degrees[rows], 1).astype(jnp.float32)
        return jnp.sum(vals, axis=-1) / deg

    def sample_neighbor(self, key: jax.Array, v: jax.Array) -> jax.Array:
        """Uniform neighbor of node v (scalar); v must have degree >= 1."""
        j = jax.random.randint(key, (), 0, jnp.maximum(self.degrees[v], 1))
        return self.neighbors[v, j]

    # -------------------------------------------------------- derived graphs
    def block_graph(self, block_size: int) -> "Topology":
        """Aggregate topology over contiguous node blocks of ``block_size``.

        Block b = nodes [b*s, (b+1)*s). Blocks b1, b2 are adjacent iff some
        edge connects them; every block is adjacent to itself. This is the
        paper's §4.2 "aggregate subset graph" generalized from the ring to
        arbitrary contact networks; SIRS-style models use it for their
        block-granular dependence footprints.
        """
        n, s = self.n_nodes, int(block_size)
        assert n % s == 0, "block_size must divide n_nodes"
        m = n // s
        blk = jnp.arange(n, dtype=jnp.int32) // s                # [N]
        nbr_blk = jnp.where(self.neighbors >= 0,
                            self.neighbors // s, PAD)            # [N, D]
        adj = jnp.zeros((m, m), dtype=bool)
        rows = jnp.repeat(blk[:, None], self.max_degree, axis=1)
        adj = adj.at[rows.reshape(-1),
                     jnp.where(nbr_blk < 0, 0, nbr_blk).reshape(-1)].max(
            (nbr_blk >= 0).reshape(-1))
        adj = adj | adj.T | jnp.eye(m, dtype=bool)
        return from_adjacency(adj, allow_self_loops=True)

    def adjacency(self) -> jax.Array:
        """Dense [n, n] bool adjacency (diagnostics / small graphs)."""
        n = self.n_nodes
        adj = jnp.zeros((n, n), dtype=bool)
        rows = jnp.repeat(jnp.arange(n, dtype=jnp.int32)[:, None],
                          self.max_degree, axis=1)
        cols = jnp.where(self.neighbors < 0, 0, self.neighbors)
        return adj.at[rows.reshape(-1), cols.reshape(-1)].max(
            (self.neighbors >= 0).reshape(-1))


def from_adjacency(adj: jax.Array, *, max_degree: int | None = None,
                   allow_self_loops: bool = False) -> Topology:
    """Build a Topology from a dense boolean adjacency matrix.

    Pure-jnp and jittable when ``max_degree`` is given (a static bound on
    row degree); when None, it is computed from the concrete matrix on the
    host. A row with more than ``max_degree`` neighbors keeps only its
    ``max_degree`` lowest-id ones (degrees are clamped to match, so the
    table stays self-consistent) — pick a generous bound when jitting
    random-graph generators. Rows are packed neighbor-first via a stable
    argsort, preserving ascending neighbor-id order within each row.
    """
    adj = jnp.asarray(adj, dtype=bool)
    n = adj.shape[0]
    if not allow_self_loops:
        adj = adj & ~jnp.eye(n, dtype=bool)
    degrees = jnp.sum(adj, axis=1).astype(jnp.int32)
    if max_degree is None:
        max_degree = max(int(jnp.max(degrees)), 1)  # host-side (concrete)
    degrees = jnp.minimum(degrees, max_degree)
    # Stable sort puts True entries first while keeping column order.
    order = jnp.argsort(~adj, axis=1, stable=True)[:, :max_degree]
    slot = jnp.arange(max_degree, dtype=jnp.int32)[None, :]
    nbrs = jnp.where(slot < degrees[:, None], order, PAD).astype(jnp.int32)
    return Topology(neighbors=nbrs, degrees=degrees)
