"""Padded-CSR contact topology — the substrate for localized dynamics.

The paper's protocol only assumes updates are *localized*; the structure of
the contact network is what determines how much parallelism the record check
exposes (cf. Fachada et al. on spatial decomposition). ``Topology`` is the
repo-wide representation of that network: a fixed-width neighbor table

    neighbors : [n_nodes, max_degree] int32, row v lists v's neighbors,
                padded with -1 past degrees[v]
    degrees   : [n_nodes] int32

which is the SPMD-friendly dual of a CSR adjacency — every gather is a
rectangular ``neighbors[v]`` with a static trailing dim, so model code can
vmap/jit over it freely. The -1 padding convention matches the conflict
kernel's "unused id slot" convention, letting ``neighbors[v]`` be dropped
directly into a task's read-id footprint.

Registered as a pytree so a Topology can be closed over by jitted functions
or passed through them as an argument.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

PAD = -1  # unused neighbor slot; also "unused id" in the conflict kernel

#: Largest node count for which dense [n, n] helpers are allowed. Above
#: this, adjacency()/from_adjacency() would silently allocate multi-GiB
#: boolean matrices; the sparse edge-list path (from_edges) has no limit.
DENSE_LIMIT = 1 << 14


def _check_dense(n: int, what: str) -> None:
    if n > DENSE_LIMIT:
        raise ValueError(
            f"{what} would materialize a dense [{n}, {n}] array "
            f"(~{n * n / 2**30:.1f} GiB as bool); refusing above "
            f"n = {DENSE_LIMIT}. Use the padded-CSR form directly "
            "(Topology.neighbors / from_edges) — the dense helpers exist "
            "for small-n diagnostics only.")


@jax.tree_util.register_pytree_node_class
@dataclass(frozen=True)
class Topology:
    """Undirected contact graph in padded neighbor-table form."""

    neighbors: jax.Array  # [n_nodes, max_degree] int32, -1 padded
    degrees: jax.Array    # [n_nodes] int32

    def tree_flatten(self):
        return (self.neighbors, self.degrees), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # ---------------------------------------------------------- properties
    @property
    def n_nodes(self) -> int:
        return self.neighbors.shape[0]

    @property
    def max_degree(self) -> int:
        return self.neighbors.shape[1]

    @property
    def n_edges(self) -> jax.Array:
        """Undirected edge count. A proper edge appears in two rows, a
        self-loop (block graphs have them) in one."""
        n = self.neighbors.shape[0]
        loops = jnp.sum(jnp.any(
            self.neighbors == jnp.arange(n, dtype=jnp.int32)[:, None],
            axis=1))
        return (jnp.sum(self.degrees) + loops) // 2

    # ------------------------------------------------------------- queries
    def neighbor_mask(self) -> jax.Array:
        """[n_nodes, max_degree] bool — True where a slot holds a neighbor.

        Table-shaped (O(n · max_degree), like ``neighbors`` itself), so —
        unlike ``adjacency()`` — it is safe at any n; the dense [n, n]
        mask is exactly what ``adjacency()`` guards against.
        """
        return self.neighbors >= 0

    def edge_list(self) -> tuple[jax.Array, jax.Array]:
        """(edges [n·max_degree, 2] int32, valid [n·max_degree] bool):
        every (v, neighbor) slot of the table, one direction per slot.
        Feeding this back through ``from_edges`` reproduces the topology;
        generators use it to append edges without going dense."""
        src = jnp.repeat(jnp.arange(self.n_nodes, dtype=jnp.int32),
                         self.max_degree)
        dst = self.neighbors.reshape(-1)
        return jnp.stack([src, dst], axis=1), dst >= 0

    def gather(self, values: jax.Array, rows: jax.Array,
               fill=0) -> tuple[jax.Array, jax.Array]:
        """values[neighbors[rows]] with padded slots replaced by ``fill``.

        rows may have any leading shape; returns (gathered, mask) with shape
        rows.shape + (max_degree,) (+ values' trailing dims).
        """
        nbrs = self.neighbors[rows]
        mask = nbrs >= 0
        safe = jnp.where(mask, nbrs, 0)
        out = values[safe]
        bshape = mask.shape + (1,) * (out.ndim - mask.ndim)
        return jnp.where(mask.reshape(bshape), out, fill), mask

    def neighbor_fraction(self, indicator: jax.Array,
                          rows: jax.Array) -> jax.Array:
        """Mean of a boolean per-node indicator over each row's neighbors
        (0 where degree is 0) — e.g. the infected fraction in epidemics."""
        vals, _ = self.gather(indicator.astype(jnp.float32), rows, fill=0.0)
        deg = jnp.maximum(self.degrees[rows], 1).astype(jnp.float32)
        return jnp.sum(vals, axis=-1) / deg

    def sample_neighbor(self, key: jax.Array, v: jax.Array) -> jax.Array:
        """Uniform neighbor of node v (scalar); v must have degree >= 1."""
        j = jax.random.randint(key, (), 0, jnp.maximum(self.degrees[v], 1))
        return self.neighbors[v, j]

    # -------------------------------------------------------- derived graphs
    def block_graph(self, block_size: int) -> "Topology":
        """Aggregate topology over contiguous node blocks of ``block_size``.

        Block b = nodes [b*s, (b+1)*s). Blocks b1, b2 are adjacent iff some
        edge connects them; every block is adjacent to itself. This is the
        paper's §4.2 "aggregate subset graph" generalized from the ring to
        arbitrary contact networks; SIRS-style models use it for their
        block-granular dependence footprints. Built through the sparse
        edge-list path, so it works for any n the neighbor table fits.
        """
        n, s = self.n_nodes, int(block_size)
        assert n % s == 0, "block_size must divide n_nodes"
        m = n // s
        blk_src = jnp.repeat(jnp.arange(n, dtype=jnp.int32) // s,
                             self.max_degree)                     # [N*D]
        blk_dst = jnp.where(self.neighbors >= 0,
                            self.neighbors // s, PAD).reshape(-1)  # [N*D]
        loops = jnp.arange(m, dtype=jnp.int32)
        edges = jnp.concatenate([
            jnp.stack([blk_src, blk_dst], axis=1),
            jnp.stack([loops, loops], axis=1),
        ])
        return from_edges(m, edges, allow_self_loops=True)

    def adjacency(self) -> jax.Array:
        """Dense [n, n] bool adjacency — small-n diagnostics only; raises
        above DENSE_LIMIT nodes instead of allocating O(n²)."""
        n = self.n_nodes
        _check_dense(n, "Topology.adjacency()")
        adj = jnp.zeros((n, n), dtype=bool)
        rows = jnp.repeat(jnp.arange(n, dtype=jnp.int32)[:, None],
                          self.max_degree, axis=1)
        cols = jnp.where(self.neighbors < 0, 0, self.neighbors)
        return adj.at[rows.reshape(-1), cols.reshape(-1)].max(
            (self.neighbors >= 0).reshape(-1))


def from_adjacency(adj: jax.Array, *, max_degree: int | None = None,
                   allow_self_loops: bool = False) -> Topology:
    """Build a Topology from a dense boolean adjacency matrix.

    Small-n diagnostics path (raises above DENSE_LIMIT — use
    ``from_edges`` for anything larger). Pure-jnp and jittable when
    ``max_degree`` is given (a static bound on row degree); when None, it
    is computed from the concrete matrix on the host. A row with more
    than ``max_degree`` neighbors keeps only its ``max_degree`` lowest-id
    ones (degrees are clamped to match, so the table stays
    self-consistent) — pick a generous bound when jitting random-graph
    generators. Rows are packed neighbor-first via a stable argsort,
    preserving ascending neighbor-id order within each row.
    """
    adj = jnp.asarray(adj, dtype=bool)
    n = adj.shape[0]
    _check_dense(n, "from_adjacency()")
    if not allow_self_loops:
        adj = adj & ~jnp.eye(n, dtype=bool)
    degrees = jnp.sum(adj, axis=1).astype(jnp.int32)
    if max_degree is None:
        max_degree = max(int(jnp.max(degrees)), 1)  # host-side (concrete)
    degrees = jnp.minimum(degrees, max_degree)
    # Stable sort puts True entries first while keeping column order.
    order = jnp.argsort(~adj, axis=1, stable=True)[:, :max_degree]
    slot = jnp.arange(max_degree, dtype=jnp.int32)[None, :]
    nbrs = jnp.where(slot < degrees[:, None], order, PAD).astype(jnp.int32)
    return Topology(neighbors=nbrs, degrees=degrees)


def _lex_order(skey: jax.Array, dkey: jax.Array, n: int) -> jax.Array:
    """Permutation sorting entries by (skey, dkey) lexicographically.

    Concrete inputs (every generator builds eagerly) take the bucketed
    by-source compaction: an LSD counting sort on the host. The combined
    key src·(n+1)+dst is cut into 16-bit digits and each digit gets one
    stable counting-sort pass (numpy's stable argsort on uint16 is a
    radix/counting sort in C), low digit first — after the final
    (highest, source-side) pass every source bucket is contiguous with
    its targets ascending. O(E) per pass, ceil(bits/16) passes — at
    n = 10^6 that is 3 passes and ~3× faster than XLA's variadic
    comparison sort, which used to dominate the 10^6-node builds (~3-5 s
    of a chunked-BA build). Traced inputs (jitted builds) keep the jnp
    lexsort — identical order, so the two paths are bit-identical
    (property-pinned).
    """
    if isinstance(skey, jax.core.Tracer) or isinstance(dkey, jax.core.Tracer):
        return jnp.lexsort((dkey, skey))
    import numpy as np

    s = np.asarray(skey).astype(np.uint64)
    d = np.asarray(dkey).astype(np.uint64)
    key = s * np.uint64(n + 1) + d           # sentinel keys sort last
    nbits = max(int(n) * (int(n) + 1) + int(n), 1).bit_length()
    digits = [((key >> np.uint64(k)) & np.uint64(0xFFFF)).astype(np.uint16)
              for k in range(0, nbits, 16)]
    order = np.argsort(digits[0], kind="stable")
    for dig in digits[1:]:
        order = order[np.argsort(dig[order], kind="stable")]
    return jnp.asarray(order)


def from_edges(n: int, edges: jax.Array, *, max_degree: int | None = None,
               symmetrize: bool = True, allow_self_loops: bool = False,
               valid: jax.Array | None = None) -> Topology:
    """Build a Topology from an [E, 2] int32 edge array — never [n, n].

    The segment-sorted compaction behind every large-scale generator:
    O(E) time for concrete inputs (bucketed by-source counting sort +
    per-bucket dedup; O(E log E) under jit), O(E) memory, so 10^6-node
    graphs build comfortably on CPU. Semantics match ``from_adjacency``
    exactly (tests pin the two bit-identically on shared edge sets):

      * an edge may appear in any direction and any number of times —
        entries are symmetrized (unless ``symmetrize=False``, for inputs
        that already list both directions) and duplicates collapse;
      * entries with a negative endpoint, an endpoint >= n, or
        ``valid[e] == False`` are dropped, so callers can pad to a static
        E and stay jittable;
      * self loops are dropped unless ``allow_self_loops`` (block graphs
        carry them);
      * ``max_degree=None`` computes the tight bound host-side (not
        jittable); a static bound keeps the build jittable, and rows
        beyond it keep their ``max_degree`` lowest-id neighbors with
        degrees clamped to match;
      * neighbor rows ascend by node id, padded with -1.
    """
    edges = jnp.asarray(edges, dtype=jnp.int32)
    src, dst = edges[:, 0], edges[:, 1]
    ok = (src >= 0) & (dst >= 0) & (src < n) & (dst < n)
    if valid is not None:
        ok = ok & valid
    if not allow_self_loops:
        ok = ok & (src != dst)
    if symmetrize:
        src, dst = (jnp.concatenate([src, dst]),
                    jnp.concatenate([dst, src]))
        ok = jnp.concatenate([ok, ok])
    # Sentinel n sinks dropped entries past every real segment in the sort.
    skey = jnp.where(ok, src, n)
    dkey = jnp.where(ok, dst, n)
    order = _lex_order(skey, dkey, n)      # primary src, secondary dst
    s, d = skey[order], dkey[order]
    dup = jnp.concatenate([jnp.zeros((1,), bool),
                           (s[1:] == s[:-1]) & (d[1:] == d[:-1])])
    keep = (s < n) & ~dup
    deg = jax.ops.segment_sum(keep.astype(jnp.int32), s,
                              num_segments=n + 1)[:n]
    if max_degree is None:
        max_degree = max(int(jnp.max(deg)), 1) if n else 1  # host-side
    # Slot of each kept entry within its row: rank among kept entries
    # minus the number kept in earlier segments. Sorted order makes rows
    # contiguous and ascending in dst, mirroring from_adjacency's packing.
    rank = jnp.cumsum(keep.astype(jnp.int32)) - 1
    cdeg = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(deg)])
    slot = rank - cdeg[jnp.minimum(s, n)]
    keep = keep & (slot < max_degree)
    rows = jnp.where(keep, s, n)           # n = out of bounds -> dropped
    nbrs = jnp.full((n, max_degree), PAD, dtype=jnp.int32)
    nbrs = nbrs.at[rows, jnp.where(keep, slot, 0)].set(d, mode="drop")
    return Topology(neighbors=nbrs,
                    degrees=jnp.minimum(deg, max_degree).astype(jnp.int32))
