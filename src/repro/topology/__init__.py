"""Contact-topology subsystem: padded-CSR neighbor tables + generators.

  graph.py       — Topology (neighbors [N, max_deg] int32, -1 padded),
                   block aggregation, masked gathers
  generators.py  — ring-k, 2D lattice (von Neumann / Moore),
                   Watts-Strogatz, Erdos-Renyi, Barabasi-Albert, complete

The -1 padding convention is shared with the conflict kernel's id
footprints, so neighbor rows drop directly into task read sets.
"""
from repro.topology.generators import (
    barabasi_albert,
    complete,
    connect_isolated,
    erdos_renyi,
    lattice2d,
    ring,
    watts_strogatz,
)
from repro.topology.graph import PAD, Topology, from_adjacency

__all__ = [
    "Topology",
    "from_adjacency",
    "PAD",
    "ring",
    "lattice2d",
    "watts_strogatz",
    "erdos_renyi",
    "barabasi_albert",
    "complete",
    "connect_isolated",
]

GENERATORS = {
    "ring": ring,
    "lattice2d": lattice2d,
    "watts_strogatz": watts_strogatz,
    "erdos_renyi": erdos_renyi,
    "barabasi_albert": barabasi_albert,
    "complete": complete,
}
