"""Contact-topology subsystem: padded-CSR neighbor tables + generators.

  graph.py       — Topology (neighbors [N, max_deg] int32, -1 padded),
                   block aggregation, masked gathers, the segment-sorted
                   ``from_edges`` builder (sparse path, any n)
  generators.py  — ring-k, 2D lattice (von Neumann / Moore),
                   Watts-Strogatz, Erdos-Renyi, Barabasi-Albert, complete
                   — all edge-list based; 10^6-node graphs build on CPU

The -1 padding convention is shared with the conflict kernel's id
footprints, so neighbor rows drop directly into task read sets. Dense
[n, n] helpers (``adjacency``/``from_adjacency``) are small-n diagnostics
and refuse above DENSE_LIMIT nodes.
"""
from repro.topology.generators import (
    barabasi_albert,
    complete,
    connect_isolated,
    erdos_renyi,
    lattice2d,
    ring,
    watts_strogatz,
)
from repro.topology.graph import (
    DENSE_LIMIT,
    PAD,
    Topology,
    from_adjacency,
    from_edges,
)

__all__ = [
    "Topology",
    "from_adjacency",
    "from_edges",
    "DENSE_LIMIT",
    "PAD",
    "ring",
    "lattice2d",
    "watts_strogatz",
    "erdos_renyi",
    "barabasi_albert",
    "complete",
    "connect_isolated",
]

GENERATORS = {
    "ring": ring,
    "lattice2d": lattice2d,
    "watts_strogatz": watts_strogatz,
    "erdos_renyi": erdos_renyi,
    "barabasi_albert": barabasi_albert,
    "complete": complete,
}
