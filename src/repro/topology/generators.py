"""Pure-JAX contact-network generators, all returning padded-CSR Topology.

Every generator is deterministic in its ``key`` and built from jnp ops, so
it can run under jit when its shape parameters (n, max_degree, ...) are
static. All families construct through the segment-sorted edge-list
builder (``graph.from_edges``): edges are materialized as [E, 2] arrays,
sorted by source, and compacted straight into the padded-CSR table —
nothing ever allocates [n, n], so 10^6-node graphs build on CPU in
seconds. The dense path survives only behind ``from_adjacency`` for
small-n diagnostics (and ``complete``, which is inherently dense).

Conventions: undirected simple graphs (no self loops, no multi-edges);
neighbor rows ascend by node id; padding id is -1 (graph.PAD).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.topology.graph import (
    Topology,
    _check_dense,
    from_adjacency,
    from_edges,
)

__all__ = [
    "ring",
    "lattice2d",
    "watts_strogatz",
    "erdos_renyi",
    "barabasi_albert",
    "complete",
    "connect_isolated",
]


def connect_isolated(topo: Topology, key: jax.Array) -> Topology:
    """Attach every isolated node to one uniformly-random other node.

    Random families (Erdos-Renyi at low p, heavily-rewired Watts-Strogatz)
    can leave degree-0 nodes, which sampling-based dynamics (voter,
    network Axelrod) reject — this is the standard patch-up when those
    dynamics need a cover of the whole population.
    """
    n = topo.n_nodes
    v = jnp.arange(n, dtype=jnp.int32)
    iso = topo.degrees == 0
    partner = jax.random.randint(key, (n,), 0, n - 1, dtype=jnp.int32)
    partner = jnp.where(partner >= v, partner + 1, partner)
    edges, valid = topo.edge_list()
    patch = jnp.stack([v, jnp.where(iso, partner, -1)], axis=1)
    return from_edges(n, jnp.concatenate([edges, patch]),
                      valid=jnp.concatenate([valid, iso]))


def ring(n: int, k: int) -> Topology:
    """Ring lattice: node v connects to v +/- 1..k/2 (mod n). k even."""
    assert k % 2 == 0 and 0 < k < n, "need even k with 0 < k < n"
    half = k // 2
    v = jnp.arange(n, dtype=jnp.int32)[:, None]
    offs = jnp.concatenate([jnp.arange(1, half + 1),
                            -jnp.arange(1, half + 1)]).astype(jnp.int32)
    nbrs = (v + offs[None, :]) % n
    nbrs = jnp.sort(nbrs, axis=1)
    deg = jnp.full((n,), k, dtype=jnp.int32)
    return Topology(neighbors=nbrs.astype(jnp.int32), degrees=deg)


def lattice2d(height: int, width: int, *, neighborhood: str = "von_neumann",
              periodic: bool = True) -> Topology:
    """2D grid, row-major node ids. von_neumann = 4-neighborhood,
    moore = 8-neighborhood; periodic wraps at the edges (torus).

    Edge-list build: one [n, |offs|] candidate block, masked for open
    boundaries; wraparound collisions on skinny grids dedup in
    ``from_edges`` (they used to dedup through a dense adjacency).
    """
    if neighborhood == "von_neumann":
        offs = [(-1, 0), (1, 0), (0, -1), (0, 1)]
    elif neighborhood == "moore":
        offs = [(dr, dc) for dr in (-1, 0, 1) for dc in (-1, 0, 1)
                if (dr, dc) != (0, 0)]
    else:
        raise ValueError(f"unknown neighborhood {neighborhood!r}")
    rows = jnp.arange(height, dtype=jnp.int32)[:, None]
    cols = jnp.arange(width, dtype=jnp.int32)[None, :]
    nbr_list, mask_list = [], []
    for dr, dc in offs:
        rr, cc = rows + dr, cols + dc
        if periodic:
            valid = jnp.ones((height, width), dtype=bool)
            rr, cc = rr % height, cc % width
        else:
            valid = (rr >= 0) & (rr < height) & (cc >= 0) & (cc < width)
            rr, cc = rr % height, cc % width
        nbr_list.append((rr * width + cc).reshape(-1))
        mask_list.append(jnp.broadcast_to(valid, (height, width)).reshape(-1))
    n = height * width
    src = jnp.repeat(jnp.arange(n, dtype=jnp.int32)[:, None],
                     len(offs), axis=1)
    dst = jnp.stack(nbr_list, axis=1).astype(jnp.int32)    # [N, |offs|]
    mask = jnp.stack(mask_list, axis=1)
    edges = jnp.stack([src.reshape(-1), dst.reshape(-1)], axis=1)
    return from_edges(n, edges, valid=mask.reshape(-1),
                      max_degree=len(offs))


def watts_strogatz(n: int, k: int, beta: float, key: jax.Array,
                   *, max_degree: int | None = None) -> Topology:
    """Small-world rewiring of a ring-k lattice (Watts & Strogatz 1998).

    Each clockwise edge (v, v+j), j = 1..k/2, is rewired with probability
    beta to (v, u) with u uniform != v. A rewire that lands on an existing
    edge is dropped (standard simple-graph variant), so degrees may vary
    around k. max_degree defaults to a host-computed tight bound. The
    [n, k/2] clockwise edge list feeds ``from_edges`` directly — the same
    draws as the historic dense build, at O(n·k) memory.
    """
    assert k % 2 == 0 and 0 < k < n, "need even k with 0 < k < n"
    half = k // 2
    k_rew, k_tgt = jax.random.split(key)
    v = jnp.arange(n, dtype=jnp.int32)[:, None]               # [n, 1]
    j = jnp.arange(1, half + 1, dtype=jnp.int32)[None, :]     # [1, half]
    rewire = jax.random.uniform(k_rew, (n, half)) < beta
    u = jax.random.randint(k_tgt, (n, half), 0, n - 1, dtype=jnp.int32)
    u = jnp.where(u >= v, u + 1, u)                           # uniform != v
    tgt = jnp.where(rewire, u, (v + j) % n)                   # [n, half]
    src = jnp.broadcast_to(v, (n, half))
    edges = jnp.stack([src.reshape(-1), tgt.reshape(-1)], axis=1)
    return from_edges(n, edges, max_degree=max_degree)


def erdos_renyi(n: int, p: float, key: jax.Array,
                *, max_degree: int | None = None) -> Topology:
    """Sparse Erdos-Renyi: edge count E ~ Binomial(n(n-1)/2, p), then the
    first E *distinct* pairs of a uniform candidate stream (sequential
    draw-ignore-repeats is exactly uniform sampling without replacement,
    so this realizes G(n, m ~ Binomial) = G(n, p) — the fast equivalence
    igraph/networkx gnm builds on). The historic per-pair Bernoulli build
    needed an [n, n] uniform draw; this one is O(E log E), so p ~ c/n
    graphs construct at n = 10^6.
    """
    n_pairs = n * (n - 1) // 2
    mean = n_pairs * p
    # target unique count: mean + 6 sigma covers the binomial tail
    target = mean + 6.0 * math.sqrt(max(mean * (1.0 - p), 1.0)) + 16
    target = min(target, float(n_pairs)) if n_pairs else 1.0
    if target >= 0.98 * n_pairs:
        # near-complete regime: the candidate stream can't cover the
        # coupon-collector tail, so enumerate the pairs and Bernoulli
        # each — exact for any p, and O(n_pairs) is proportional to the
        # output graph itself here.
        i, j = jnp.triu_indices(n, k=1)
        live = jax.random.uniform(key, (n_pairs,)) < p
        edges = jnp.stack([jnp.where(live, i.astype(jnp.int32), -1),
                           j.astype(jnp.int32)], axis=1)
        return from_edges(n, edges, max_degree=max_degree)
    # candidate stream sized by the coupon-collector expectation of draws
    # needed to see `target` distinct pairs
    frac = target / n_pairs
    cap = int(-n_pairs * math.log1p(-frac) * 1.05 + 64)
    k_cnt, k_a, k_b = jax.random.split(key, 3)
    e = jax.random.binomial(k_cnt, n=float(n_pairs), p=p).astype(jnp.int32)
    a = jax.random.randint(k_a, (cap,), 0, n, dtype=jnp.int32)
    b = jax.random.randint(k_b, (cap,), 0, n - 1, dtype=jnp.int32)
    b = jnp.where(b >= a, b + 1, b)          # uniform over ordered pairs
    lo, hi = jnp.minimum(a, b), jnp.maximum(a, b)
    # first occurrence of each pair in *draw order*: group by pair with
    # draw index as tiebreak, flag group heads, scatter back
    idx = jnp.arange(cap)
    order = jnp.lexsort((idx, hi, lo))
    ls, lh = lo[order], hi[order]
    head = jnp.concatenate([jnp.ones((1,), bool),
                            (ls[1:] != ls[:-1]) | (lh[1:] != lh[:-1])])
    first = jnp.zeros((cap,), bool).at[order].set(head)
    live = first & (jnp.cumsum(first) - 1 < e)   # first e distinct pairs
    edges = jnp.stack([jnp.where(live, lo, -1), hi], axis=1)
    return from_edges(n, edges, max_degree=max_degree)


def barabasi_albert(n: int, m: int, key: jax.Array,
                    *, max_degree: int | None = None,
                    chunk: int | None = None) -> Topology:
    """Preferential attachment (Barabasi & Albert 1999): start from a
    complete seed of m+1 nodes; each arriving node attaches to m distinct
    existing nodes drawn from the *edge-endpoint multiset* (probability
    proportional to degree, duplicates rejected — the standard
    repeated-nodes realization). O(n·m) memory and O(m) expected work per
    arrival, replacing the dense-adjacency scan that capped n at ~10^4.

    ``chunk=None`` (default) is the exact sequential realization: the
    endpoint multiset grows after every arrival, one ``lax.scan`` step per
    node — the last O(n)-length sequential loop among the generators
    (~1 min at n = 10^6 on CPU). ``chunk=C`` is the *chunked attachment*
    fast path: arrivals are processed in blocks of C with degrees
    (the endpoint multiset) frozen at each block start, so the per-block
    draws vectorize (one vmap over the block instead of C scan steps) and
    the sequential length drops to n/C. Within a block, arrivals cannot
    draw each other (their endpoints are not in the frozen multiset) and
    duplicate/self edges remain impossible, so the result is a valid
    simple BA-style graph whose attachment probabilities lag by at most
    one block — the standard batched-PA approximation. ``chunk=1`` is
    bit-identical to the sequential path (regression-tested), since the
    multiset is then frozen exactly at every arrival.
    """
    assert 1 <= m < n
    seed_sz = m + 1
    si, sj = jnp.triu_indices(seed_sz, k=1)
    seed_edges = jnp.stack([si, sj], axis=1).astype(jnp.int32)
    n_seed_ends = seed_sz * m                       # == 2 * len(seed_edges)
    n_arrivals = n - seed_sz

    def draw_targets(t, ends, fill):
        """m distinct endpoints for arrival t, drawn uniformly from the
        multiset prefix ends[:fill] (rejection on duplicates)."""

        def undrawn(c):
            return c[0] < m

        def draw(c):
            cnt, sel, kk = c
            kk, sub = jax.random.split(kk)
            cand = ends[jax.random.randint(sub, (), 0, fill)]
            fresh = ~jnp.any(sel == cand)
            sel = jnp.where(fresh, sel.at[cnt].set(cand), sel)
            return cnt + fresh.astype(jnp.int32), sel, kk

        _, targets, _ = jax.lax.while_loop(
            undrawn, draw, (jnp.int32(0), jnp.full((m,), -1, jnp.int32),
                            jax.random.fold_in(key, t)))
        return targets

    def attach(carry, t):
        ends, fill = carry
        targets = draw_targets(t, ends, fill)
        ends = jax.lax.dynamic_update_slice(ends, targets, (fill,))
        ends = jax.lax.dynamic_update_slice(
            ends, jnp.full((m,), t, jnp.int32), (fill + m,))
        return (ends, fill + 2 * m), targets

    if chunk is None:
        cap = n_seed_ends + 2 * m * n_arrivals      # endpoint slots, exact
        ends0 = jnp.zeros((cap,), jnp.int32).at[:n_seed_ends].set(
            jnp.concatenate([si, sj]).astype(jnp.int32))

        arrivals = jnp.arange(seed_sz, n, dtype=jnp.int32)
        (_, _), tgts = jax.lax.scan(attach, (ends0, jnp.int32(n_seed_ends)),
                                    arrivals)
        new_edges = jnp.stack([jnp.repeat(arrivals, m), tgts.reshape(-1)],
                              axis=1)
        return from_edges(n, jnp.concatenate([seed_edges, new_edges]),
                          max_degree=max_degree)

    # chunked attachment: freeze the endpoint multiset per block of C
    # arrivals; the block's draws vectorize (vmap), and the sequential
    # scan shrinks to ceil(n_arrivals / C) steps. The first C arrivals
    # attach through the exact sequential path — a frozen block must
    # never exceed the graph it draws from, or the whole block piles
    # onto the tiny seed and hub degrees explode (at n = 10^5, C = 1024
    # the warm-up keeps max_degree within ~2x of the sequential build).
    c = int(chunk)
    assert c >= 1, "chunk must be >= 1"
    warm = min(n_arrivals, c)
    n_blocks = -(-(n_arrivals - warm) // c)
    # padded capacity: the last block may hold phantom arrivals (t >= n)
    # whose slab entries land past the true fill and are never read
    cap = n_seed_ends + 2 * m * (warm + n_blocks * c)
    ends0 = jnp.zeros((cap,), jnp.int32).at[:n_seed_ends].set(
        jnp.concatenate([si, sj]).astype(jnp.int32))

    def attach_block(carry, b):
        ends, fill = carry  # fill frozen for the whole block
        ts = seed_sz + warm + b * c + jnp.arange(c, dtype=jnp.int32)
        targets = jax.vmap(lambda t: draw_targets(t, ends, fill))(ts)
        # per-arrival slab [targets..., t repeated m] — the same endpoint
        # layout the sequential path appends, arrival by arrival
        slab = jnp.concatenate(
            [targets, jnp.broadcast_to(ts[:, None], (c, m))],
            axis=1).reshape(-1)
        ends = jax.lax.dynamic_update_slice(ends, slab, (fill,))
        return (ends, fill + 2 * m * c), targets

    # jit both scans as one unit: eager dispatch of the vmapped
    # rejection loop costs more than the draws themselves (the point of
    # chunking is n/C compiled steps of vectorized work)
    def build(ends0):
        warm_arrivals = seed_sz + jnp.arange(warm, dtype=jnp.int32)
        (ends, fill), tgts_warm = jax.lax.scan(
            attach, (ends0, jnp.int32(n_seed_ends)), warm_arrivals)
        if n_blocks:
            (_, _), tgts_blk = jax.lax.scan(
                attach_block, (ends, fill),
                jnp.arange(n_blocks, dtype=jnp.int32))
            return tgts_warm, tgts_blk.reshape(-1, m)
        return tgts_warm, jnp.zeros((0, m), jnp.int32)

    tgts_warm, tgts_blk = jax.jit(build)(ends0)
    tgts = jnp.concatenate([tgts_warm, tgts_blk])
    ts_all = seed_sz + jnp.arange(warm + n_blocks * c, dtype=jnp.int32)
    new_edges = jnp.stack([jnp.repeat(ts_all, m), tgts.reshape(-1)],
                          axis=1)
    valid = jnp.concatenate([
        jnp.ones((seed_edges.shape[0],), bool),
        jnp.repeat(ts_all < n, m),          # drop the phantom tail
    ])
    return from_edges(n, jnp.concatenate([seed_edges, new_edges]),
                      valid=valid, max_degree=max_degree)


def complete(n: int) -> Topology:
    """Complete graph K_n (the seed Axelrod mixing assumption). Inherently
    dense — the table alone is [n, n-1] — so it stays on the
    ``from_adjacency`` diagnostics path and its size guard (checked before
    the [n, n] argument is even allocated)."""
    _check_dense(n, "complete()")
    return from_adjacency(jnp.ones((n, n), dtype=bool), max_degree=n - 1)
