"""Pure-JAX contact-network generators, all returning padded-CSR Topology.

Every generator is deterministic in its ``key`` and built from jnp ops, so
it can run under jit when its shape parameters (n, max_degree, ...) are
static. Random families (Watts-Strogatz, Erdos-Renyi, Barabasi-Albert) go
through a dense [n, n] boolean adjacency — fine for the n <= O(10^4) regime
these scenarios target; a sparse builder is a later scaling item.

Conventions: undirected simple graphs (no self loops, no multi-edges);
neighbor rows ascend by node id; padding id is -1 (graph.PAD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.topology.graph import Topology, from_adjacency

__all__ = [
    "ring",
    "lattice2d",
    "watts_strogatz",
    "erdos_renyi",
    "barabasi_albert",
    "complete",
    "connect_isolated",
]


def connect_isolated(topo: Topology, key: jax.Array) -> Topology:
    """Attach every isolated node to one uniformly-random other node.

    Random families (Erdos-Renyi at low p, heavily-rewired Watts-Strogatz)
    can leave degree-0 nodes, which sampling-based dynamics (voter,
    network Axelrod) reject — this is the standard patch-up when those
    dynamics need a cover of the whole population.
    """
    n = topo.n_nodes
    adj = topo.adjacency()
    iso = topo.degrees == 0
    partner = jax.random.randint(key, (n,), 0, n - 1, dtype=jnp.int32)
    partner = jnp.where(partner >= jnp.arange(n), partner + 1, partner)
    add = jnp.zeros_like(adj).at[jnp.arange(n), partner].set(iso)
    return from_adjacency(adj | add | add.T)


def ring(n: int, k: int) -> Topology:
    """Ring lattice: node v connects to v +/- 1..k/2 (mod n). k even."""
    assert k % 2 == 0 and 0 < k < n, "need even k with 0 < k < n"
    half = k // 2
    v = jnp.arange(n, dtype=jnp.int32)[:, None]
    offs = jnp.concatenate([jnp.arange(1, half + 1),
                            -jnp.arange(1, half + 1)]).astype(jnp.int32)
    nbrs = (v + offs[None, :]) % n
    nbrs = jnp.sort(nbrs, axis=1)
    deg = jnp.full((n,), k, dtype=jnp.int32)
    return Topology(neighbors=nbrs.astype(jnp.int32), degrees=deg)


def lattice2d(height: int, width: int, *, neighborhood: str = "von_neumann",
              periodic: bool = True) -> Topology:
    """2D grid, row-major node ids. von_neumann = 4-neighborhood,
    moore = 8-neighborhood; periodic wraps at the edges (torus)."""
    if neighborhood == "von_neumann":
        offs = [(-1, 0), (1, 0), (0, -1), (0, 1)]
    elif neighborhood == "moore":
        offs = [(dr, dc) for dr in (-1, 0, 1) for dc in (-1, 0, 1)
                if (dr, dc) != (0, 0)]
    else:
        raise ValueError(f"unknown neighborhood {neighborhood!r}")
    rows = jnp.arange(height, dtype=jnp.int32)[:, None]
    cols = jnp.arange(width, dtype=jnp.int32)[None, :]
    nbr_list, mask_list = [], []
    for dr, dc in offs:
        rr, cc = rows + dr, cols + dc
        if periodic:
            valid = jnp.ones((height, width), dtype=bool)
            rr, cc = rr % height, cc % width
        else:
            valid = (rr >= 0) & (rr < height) & (cc >= 0) & (cc < width)
            rr, cc = rr % height, cc % width
        nbr_list.append((rr * width + cc).reshape(-1))
        mask_list.append(jnp.broadcast_to(valid, (height, width)).reshape(-1))
    nbrs = jnp.stack(nbr_list, axis=1).astype(jnp.int32)   # [N, |offs|]
    mask = jnp.stack(mask_list, axis=1)
    # Non-periodic small grids / periodic 2-wide grids can produce duplicate
    # neighbor ids (wraparound collisions); dedup through the adjacency.
    n = height * width
    adj = jnp.zeros((n, n), dtype=bool)
    v = jnp.repeat(jnp.arange(n, dtype=jnp.int32)[:, None], len(offs), axis=1)
    adj = adj.at[v.reshape(-1),
                 jnp.where(mask, nbrs, 0).reshape(-1)].max(mask.reshape(-1))
    return from_adjacency(adj | adj.T, max_degree=len(offs))


def watts_strogatz(n: int, k: int, beta: float, key: jax.Array,
                   *, max_degree: int | None = None) -> Topology:
    """Small-world rewiring of a ring-k lattice (Watts & Strogatz 1998).

    Each clockwise edge (v, v+j), j = 1..k/2, is rewired with probability
    beta to (v, u) with u uniform != v. A rewire that lands on an existing
    edge is dropped (standard simple-graph variant), so degrees may vary
    around k. max_degree defaults to a host-computed tight bound.
    """
    assert k % 2 == 0 and 0 < k < n, "need even k with 0 < k < n"
    half = k // 2
    k_rew, k_tgt = jax.random.split(key)
    v = jnp.arange(n, dtype=jnp.int32)[:, None]               # [n, 1]
    j = jnp.arange(1, half + 1, dtype=jnp.int32)[None, :]     # [1, half]
    rewire = jax.random.uniform(k_rew, (n, half)) < beta
    u = jax.random.randint(k_tgt, (n, half), 0, n - 1, dtype=jnp.int32)
    u = jnp.where(u >= v, u + 1, u)                           # uniform != v
    tgt = jnp.where(rewire, u, (v + j) % n)                   # [n, half]

    adj = jnp.zeros((n, n), dtype=bool)
    src = jnp.broadcast_to(v, (n, half))
    adj = adj.at[src.reshape(-1), tgt.reshape(-1)].set(True)
    adj = adj | adj.T
    return from_adjacency(adj, max_degree=max_degree)


def erdos_renyi(n: int, p: float, key: jax.Array,
                *, max_degree: int | None = None) -> Topology:
    """G(n, p): each of the n(n-1)/2 undirected edges present w.p. p."""
    u = jax.random.uniform(key, (n, n))
    upper = jnp.triu(u < p, k=1)
    adj = upper | upper.T
    return from_adjacency(adj, max_degree=max_degree)


def barabasi_albert(n: int, m: int, key: jax.Array,
                    *, max_degree: int | None = None) -> Topology:
    """Preferential attachment (Barabasi & Albert 1999): start from a
    complete seed of m+1 nodes; each arriving node attaches to m distinct
    existing nodes sampled proportionally to degree (Gumbel top-m over
    log-degree — exact weighted sampling without replacement).
    """
    assert 1 <= m < n
    seed_sz = m + 1
    adj0 = jnp.zeros((n, n), dtype=bool)
    seed_mask = (jnp.arange(n) < seed_sz)
    adj0 = adj0.at[:seed_sz, :seed_sz].set(
        ~jnp.eye(seed_sz, dtype=bool))
    deg0 = jnp.where(seed_mask, m, 0).astype(jnp.float32)

    def attach(carry, t):
        adj, deg = carry
        exists = jnp.arange(n) < t                       # nodes already in
        logits = jnp.where(exists, jnp.log(jnp.maximum(deg, 1e-9)), -jnp.inf)
        g = jax.random.gumbel(jax.random.fold_in(key, t), (n,))
        _, targets = jax.lax.top_k(logits + g, m)        # m distinct nodes
        adj = adj.at[t, targets].set(True)
        adj = adj.at[targets, t].set(True)
        deg = deg.at[targets].add(1.0)
        deg = deg.at[t].add(float(m))
        return (adj, deg), None

    (adj, _), _ = jax.lax.scan(attach, (adj0, deg0),
                               jnp.arange(seed_sz, n))
    return from_adjacency(adj, max_degree=max_degree)


def complete(n: int) -> Topology:
    """Complete graph K_n (the seed Axelrod mixing assumption)."""
    return from_adjacency(jnp.ones((n, n), dtype=bool), max_degree=n - 1)
