"""Mixture-of-Experts layer — expert-parallel, sort-based capacity dispatch.

TPU-native design (no torch-style per-expert loops):

  1. router top-k over E experts
  2. flatten (token, choice) pairs, argsort by expert id
  3. rank-within-expert via index arithmetic on the sorted ids
  4. scatter into a dense [E, C, D] buffer (capacity C, overflow dropped —
     the overflow count is reported as a metric, the wavefront analogy of
     the paper's "tasks that cannot enter the current wave")
  5. batched expert GEMMs  einsum('ecd,edf->ecf')  — experts sharded over
     the "model" mesh axis (EP); GSPMD inserts the all-to-alls at the
     sharding boundary between token-sharded and expert-sharded tensors
  6. gather back + gate-weighted combine

Arctic mode (dense_parallel): a dense SwiGLU runs in parallel with the MoE
branch and the outputs add (Snowflake Arctic's dense-MoE hybrid).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, init_swiglu, swiglu


def init_moe(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    ks = jax.random.split(key, 5)
    e, fe = m.n_experts, m.d_expert

    def expert_stack(k, d_in, d_out, scale):
        w = jax.random.normal(k, (e, d_in, d_out), jnp.float32) * scale
        return w.astype(dt)

    p = {
        "router": init_dense(ks[0], d, e, jnp.float32),  # router in f32
        "experts": {
            "w_gate": expert_stack(ks[1], d, fe, d ** -0.5),
            "w_up": expert_stack(ks[2], d, fe, d ** -0.5),
            "w_out": expert_stack(ks[3], fe, d, fe ** -0.5),
        },
    }
    if m.dense_parallel:
        p["dense_mlp"] = init_swiglu(ks[4], d, cfg.d_ff, dt)
    return p


def moe_layer(params, x, cfg):
    """x [B, S, D] -> (y [B, S, D], aux: {load_balance_loss, router_z_loss,
    overflow_fraction})."""
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    e, k = m.n_experts, m.top_k
    cap = int(n * k / e * m.capacity_factor + 1)

    xf = x.reshape(n, d)
    logits = dense(params["router"], xf.astype(jnp.float32))   # [N, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, choice = jax.lax.top_k(probs, k)                    # [N, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # ---- flatten (token, choice) pairs and sort by expert ----
    flat_e = choice.reshape(-1)                                # [N·k]
    flat_t = jnp.repeat(jnp.arange(n), k)                      # [N·k]
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]

    # rank within expert: position - first-position-of-expert
    counts = jnp.bincount(se, length=e)                        # [E]
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * k) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)                          # cap = trash

    # ---- dispatch: [E, C+1, D] buffer (+1 trash row) ----
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[se, slot].add(xf[st].astype(x.dtype))
    buf = buf[:, :cap]

    # ---- batched expert GEMMs (EP over "model") ----
    w = params["experts"]
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w["w_gate"]))
    u = jnp.einsum("ecd,edf->ecf", buf, w["w_up"])
    y = jnp.einsum("ecf,efd->ecd", g * u, w["w_out"])          # [E, C, D]

    # ---- combine ----
    contrib = y[se, jnp.where(keep, rank, 0)]                  # [N·k, D]
    contrib = jnp.where(keep[:, None], contrib, 0.0)
    out = jnp.zeros((n, d), y.dtype).at[st].add(
        contrib * sg[:, None].astype(y.dtype))

    # ---- aux losses / metrics ----
    me = jnp.mean(probs, axis=0)                               # [E]
    ce = jnp.mean(
        jax.nn.one_hot(choice, e).sum(axis=1), axis=0)         # tokens/exp
    load_balance = e * jnp.sum(me * ce) / k
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    overflow = 1.0 - jnp.mean(keep.astype(jnp.float32))

    out = out.reshape(b, s, d).astype(x.dtype)
    if m.dense_parallel:
        out = out + swiglu(params["dense_mlp"], x)
    aux = {
        "load_balance_loss": load_balance,
        "router_z_loss": z,
        "overflow_fraction": overflow,
    }
    return out, aux
