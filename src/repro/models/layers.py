"""Primitive layers — pure-pytree params, no framework.

Parameter naming is load-bearing: distributed/sharding.py assigns mesh axes
by matching path substrings ("wq", "experts/w_gate", "embed", ...). Keep
names stable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[name]


# ----------------------------------------------------------------- init
def init_dense(key, d_in, d_out, dtype, *, scale=None, bias=False):
    scale = scale if scale is not None else d_in ** -0.5
    w = jax.random.normal(key, (d_in, d_out), jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def init_rmsnorm(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def init_embedding(key, vocab, d, dtype):
    w = jax.random.normal(key, (vocab, d), jnp.float32) * 0.02
    return {"table": w.astype(dtype)}


# -------------------------------------------------------------- apply
def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def embed(p, tokens):
    return p["table"][tokens]


def swiglu(p, x):
    """p: {'w_gate','w_up','w_out'}."""
    g = jax.nn.silu(dense(p["w_gate"], x))
    u = dense(p["w_up"], x)
    return dense(p["w_out"], g * u)


def init_swiglu(key, d, f, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": init_dense(k1, d, f, dtype),
        "w_up": init_dense(k2, d, f, dtype),
        "w_out": init_dense(k3, f, d, dtype, scale=f ** -0.5),
    }


# ----------------------------------------------------------------- rope
def rope(x, positions, theta: float):
    """x [..., T, H, hd]; positions [..., T] (broadcastable)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., T, half]
    cos = jnp.cos(ang)[..., None, :]   # broadcast over heads
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels, *, z_loss: float = 0.0):
    """logits [..., V] (any float dtype), labels int [...]. Mean loss in f32.
    label == -100 masks the position out."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None].clip(0), axis=-1)[..., 0]
    nll = lse - ll
    if z_loss:
        nll = nll + z_loss * lse ** 2
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
