"""Public model API — one `Model` object per architecture config.

Pure-functional: params and streaming states are pytrees; every method is
jit/pjit-compatible. The same object serves training (loss/grads), prefill
and decode (serving), and the dry-run (ShapeDtypeStruct input specs).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import cross_entropy, dtype_of, embed
from repro.models.transformer import (
    forward_hidden,
    init_params,
    init_states,
    logits_head,
    plan_segments,
    run_encoder,
)


class Model:
    """Decoder-only families (dense / moe / ssm / hybrid / vlm)."""

    def __init__(self, cfg):
        self.cfg = cfg
        self.segments = plan_segments(cfg)

    # ----------------------------------------------------------- params
    def init(self, key):
        return init_params(self.cfg, key)

    # ------------------------------------------------------------ train
    def _embed_inputs(self, params, batch, include_prefix: bool = True):
        """Returns (x [B, Tfull, D], n_prefix) — prefix = meta tokens and/or
        stub frontend embeddings (vlm patches), prepended before text."""
        cfg = self.cfg
        x = embed(params["embed"], batch["tokens"])
        n_prefix = 0
        if include_prefix and cfg.frontend == "vision_stub" \
                and "patch_embeds" in batch:
            pe = batch["patch_embeds"].astype(x.dtype)
            x = jnp.concatenate([pe, x], axis=1)
            n_prefix += pe.shape[1]
        if include_prefix and cfg.n_prefix_tokens:
            pref = jnp.broadcast_to(
                params["prefix"][None], (x.shape[0],) + params["prefix"].shape
            ).astype(x.dtype)
            x = jnp.concatenate([pref, x], axis=1)
            n_prefix += pref.shape[1]
        return x, n_prefix

    def apply_train(self, params, batch):
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        hidden, _, aux = forward_hidden(params, x, cfg, positions=positions,
                                        mode="train")
        hidden = hidden[:, n_prefix:]
        return logits_head(params, hidden, cfg), aux

    def loss(self, params, batch):
        logits, aux = self.apply_train(params, batch)
        ce = cross_entropy(logits, batch["labels"])
        total = ce
        metrics = {"ce": ce}
        if self.cfg.moe is not None:
            total = (total + 0.01 * aux["load_balance_loss"]
                     + self.cfg.moe.router_z_loss * aux["router_z_loss"])
            metrics.update(aux)
        return total, metrics

    # ---------------------------------------------------------- serving
    def init_states(self, batch: int, max_len: int):
        cfg = self.cfg
        return {
            "segs": init_states(cfg, batch, max_len,
                                dtype=dtype_of(cfg.param_dtype)),
            "pos": jnp.zeros((batch,), jnp.int32),  # per-request timeline
        }

    def prefill(self, params, batch, states, *, chunked: bool = False,
                include_prefix: bool = True):
        """Prompt pass; returns (last-token logits [B, V], states).

        chunked=True: continuation-safe path — attention runs against the
        (possibly non-empty) cache, SSM/RWKV states carry; used by the
        serving engine's chunked prefill (straggler mitigation). The
        default one-shot path assumes an empty, exactly-sized cache and
        uses the memory-bounded chunked-attention impl.
        """
        cfg = self.cfg
        x, n_prefix = self._embed_inputs(params, batch, include_prefix)
        positions = (states["pos"][:, None]
                     + jnp.arange(x.shape[1])[None, :])
        hidden, segs, _ = forward_hidden(
            params, x, cfg, positions=positions, states=states["segs"],
            mode="chunk" if chunked else "prefill")
        logits = logits_head(params, hidden[:, -1:], cfg)[:, 0]
        return logits, {"segs": segs, "pos": states["pos"] + x.shape[1]}

    def decode_step(self, params, token, states):
        """token [B, 1] -> (logits [B, V], states)."""
        cfg = self.cfg
        x = embed(params["embed"], token)
        positions = states["pos"][:, None]
        hidden, segs, _ = forward_hidden(
            params, x, cfg, positions=positions, states=states["segs"],
            mode="decode")
        logits = logits_head(params, hidden[:, -1:], cfg)[:, 0]
        return logits, {"segs": segs, "pos": states["pos"] + 1}


class EncDecModel(Model):
    """Encoder–decoder (seamless-m4t): frontend-stub source embeddings."""

    def init_states(self, batch: int, max_len: int, src_len: int | None = None):
        st = super().init_states(batch, max_len)
        st["enc_out"] = jnp.zeros(
            (batch, src_len or max_len, self.cfg.d_model),
            dtype_of(self.cfg.param_dtype))
        return st

    def apply_train(self, params, batch):
        cfg = self.cfg
        enc_out = run_encoder(params, batch["src_embeds"].astype(
            dtype_of(cfg.param_dtype)), cfg)
        x = embed(params["embed"], batch["tokens"])
        positions = jnp.arange(x.shape[1])
        hidden, _, aux = forward_hidden(params, x, cfg, positions=positions,
                                        mode="train", enc_out=enc_out)
        return logits_head(params, hidden, cfg), aux

    def prefill(self, params, batch, states, *, chunked: bool = False,
                include_prefix: bool = True):
        cfg = self.cfg
        enc_out = run_encoder(params, batch["src_embeds"].astype(
            dtype_of(cfg.param_dtype)), cfg)
        x = embed(params["embed"], batch["tokens"])
        positions = (states["pos"][:, None]
                     + jnp.arange(x.shape[1])[None, :])
        hidden, segs, _ = forward_hidden(
            params, x, cfg, positions=positions, states=states["segs"],
            mode="chunk" if chunked else "prefill", enc_out=enc_out)
        logits = logits_head(params, hidden[:, -1:], cfg)[:, 0]
        return logits, {"segs": segs, "pos": states["pos"] + x.shape[1],
                        "enc_out": enc_out}

    def decode_step(self, params, token, states):
        cfg = self.cfg
        x = embed(params["embed"], token)
        positions = states["pos"][:, None]
        hidden, segs, _ = forward_hidden(
            params, x, cfg, positions=positions, states=states["segs"],
            mode="decode", enc_out=states["enc_out"])
        logits = logits_head(params, hidden[:, -1:], cfg)[:, 0]
        return logits, {"segs": segs, "pos": states["pos"] + 1,
                        "enc_out": states["enc_out"]}


def build_model(cfg) -> Model:
    if cfg.is_encdec:
        return EncDecModel(cfg)
    return Model(cfg)


# ------------------------------------------------------------ input specs
def input_specs(cfg, shape, *, for_decode_states: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of the given shape
    cell (no allocation). Frontend stubs (audio frames / vision patches)
    appear here as precomputed embedding inputs, per the assignment."""
    b, t = shape.global_batch, shape.seq_len
    tok = jnp.int32

    if shape.kind == "train":
        batch: dict[str, Any] = {
            "tokens": jax.ShapeDtypeStruct((b, t), tok),
            "labels": jax.ShapeDtypeStruct((b, t), tok),
        }
        if cfg.frontend == "vision_stub":
            # patches replace a prefix of the text budget (keep totals sane)
            n_patch = min(1024, t // 4)
            batch["tokens"] = jax.ShapeDtypeStruct((b, t - n_patch), tok)
            batch["labels"] = jax.ShapeDtypeStruct((b, t - n_patch), tok)
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, n_patch, cfg.d_model), jnp.bfloat16)
        if cfg.is_encdec:
            # audio stub: frame embeddings on the encoder side
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, t, cfg.d_model), jnp.bfloat16)
            batch["tokens"] = jax.ShapeDtypeStruct((b, t), tok)
            batch["labels"] = jax.ShapeDtypeStruct((b, t), tok)
        return batch

    if shape.kind == "prefill":
        batch = {"tokens": jax.ShapeDtypeStruct((b, t), tok)}
        if cfg.frontend == "vision_stub":
            n_patch = min(1024, t // 4)
            batch["tokens"] = jax.ShapeDtypeStruct((b, t - n_patch), tok)
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, n_patch, cfg.d_model), jnp.bfloat16)
        if cfg.is_encdec:
            batch["src_embeds"] = jax.ShapeDtypeStruct(
                (b, t, cfg.d_model), jnp.bfloat16)
        return batch

    # decode: one new token against a cache of length t-1
    return {"token": jax.ShapeDtypeStruct((b, 1), tok)}
