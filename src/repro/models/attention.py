"""GQA attention with three interchangeable inner implementations.

  "ref"     — materialized [T, S] logits (small tests only)
  "chunked" — pure-jnp flash-style scan over query chunks with online
              softmax and *structural* sliding-window KV slicing. This is
              the default for lowering/dry-run: peak temp is O(bq·S) per
              layer instead of O(T·S), and out-of-window KV is never read.
  "pallas"  — the kernels/flash fused kernel (TPU target; interpret on CPU)

All three share semantics (tested against each other): causal masking,
sliding window, GQA head grouping, end-alignment when S > T.

KV cache: a *ring buffer* of capacity Smax with absolute-position tracking
(`kpos`); for sliding-window layers Smax = window, so a 500k-token decode
holds only window-sized KV per layer. A full-attention cache is the same
structure with Smax >= total length (the ring never wraps).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense, rope


class KVCache(NamedTuple):
    k: jax.Array          # [B, Hkv, Smax, hd]
    v: jax.Array
    length: jax.Array     # [B] int32 — absolute tokens seen, per request
    kpos: jax.Array       # [B, Smax] int32 — absolute position per slot (-1)


def init_kv_cache(batch, n_kv_heads, smax, head_dim, dtype=jnp.bfloat16):
    return KVCache(
        k=jnp.zeros((batch, n_kv_heads, smax, head_dim), dtype),
        v=jnp.zeros((batch, n_kv_heads, smax, head_dim), dtype),
        length=jnp.zeros((batch,), jnp.int32),
        kpos=jnp.full((batch, smax), -1, jnp.int32),
    )


def init_attention(key, cfg, *, d_model=None, cross=False):
    d = d_model or cfg.d_model
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    ks = jax.random.split(key, 4)
    return {
        "wq": init_dense(ks[0], d, hq * hd, dt, bias=cfg.qkv_bias),
        "wk": init_dense(ks[1], d, hkv * hd, dt, bias=cfg.qkv_bias),
        "wv": init_dense(ks[2], d, hkv * hd, dt, bias=cfg.qkv_bias),
        "wo": init_dense(ks[3], hq * hd, d, dt,
                         scale=(hq * hd) ** -0.5 / (2 * cfg.n_layers) ** 0.5),
    }


# ----------------------------------------------------------- inner impls
def _attn_ref(q, k, v, *, causal, window, scale):
    from repro.kernels.flash.ref import attention_ref

    return attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def _attn_pallas(q, k, v, *, causal, window, scale):
    from repro.kernels.flash.ops import flash_attention

    return flash_attention(q, k, v, causal=causal, window=window, scale=scale)


def _attn_chunked(q, k, v, *, causal, window, scale, chunk,
                  gqa_expand=False):
    """Flash-style online softmax over query chunks, GQA-aware.

    q [B, H, T, hd]; k, v [B, Hkv, S, hd]. When `window` is set, each query
    chunk only reads the KV slice it can see — compute AND memory scale
    with the window, not S (the structural win of SWA).

    gqa_expand: materialize KV per q-head first. Costs group× KV bytes
    (transient) but keeps the whole attention shardable over H when Hkv
    does not divide the model axis — without it GSPMD re-shards the
    grouped [B, Hkv, G, T, hd] reshape with per-layer all-gathers
    (measured ~40x wire-byte blowup on h2o-danube, see EXPERIMENTS.md).
    """
    b, h, t, hd = q.shape
    hkv, s = k.shape[1], k.shape[2]
    group = h // hkv
    if gqa_expand and group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
        hkv, group = h, 1
    bq = min(chunk, t)
    while t % bq:       # prefix tokens can make t a non-power-of-two
        bq //= 2
    bq = max(bq, 1)
    n_chunks = t // bq
    seq_off = s - t  # end alignment
    qg = q.reshape(b, hkv, group, t, hd)

    kv_span = s if window is None else min(s, window + bq)

    def one_chunk(ci):
        q0 = ci * bq
        qc = jax.lax.dynamic_slice_in_dim(qg, q0, bq, axis=3)
        if window is None:
            k0 = 0
        else:
            k0 = jnp.clip(q0 + seq_off + bq - kv_span, 0, s - kv_span)
        kc = jax.lax.dynamic_slice_in_dim(k, k0, kv_span, axis=2)
        vc = jax.lax.dynamic_slice_in_dim(v, k0, kv_span, axis=2)

        logits = jnp.einsum(
            "bkgtd,bksd->bkgts", qc.astype(jnp.float32),
            kc.astype(jnp.float32)) * scale
        qpos = q0 + seq_off + jnp.arange(bq)[:, None]
        kpos = k0 + jnp.arange(kv_span)[None, :]
        mask = jnp.ones((bq, kv_span), bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        m = jnp.max(logits, axis=-1, keepdims=True)
        p = jnp.exp(logits - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bkgts,bksd->bkgtd", p, vc.astype(jnp.float32))
        return (o / jnp.maximum(l, 1e-30)).astype(q.dtype)

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        # checkpoint per chunk: the scan's backward would otherwise save
        # every chunk's [bq, S] logits simultaneously (measured: 46 GiB/dev
        # on a 360M model) — recomputing them caps peak temp at one chunk.
        outs = jax.lax.map(jax.checkpoint(one_chunk), jnp.arange(n_chunks))
        out = jnp.moveaxis(outs, 0, 3)          # [B,Hkv,G,nc,bq,hd]
        out = out.reshape(b, hkv, group, t, hd)
    return out.reshape(b, h, t, hd)


def attention_inner(q, k, v, *, causal=True, window=None, scale=None,
                    impl="chunked", chunk=256, gqa_expand=False):
    if scale is None:
        scale = q.shape[-1] ** -0.5
    if impl == "ref":
        return _attn_ref(q, k, v, causal=causal, window=window, scale=scale)
    if impl == "pallas":
        return _attn_pallas(q, k, v, causal=causal, window=window,
                            scale=scale)
    return _attn_chunked(q, k, v, causal=causal, window=window, scale=scale,
                         chunk=chunk, gqa_expand=gqa_expand)


def _attn_cache(q, cache: KVCache, qpos0, cfg, *, causal=True, window=None):
    """Attention of q [B, H, T, hd] against a ring-buffer cache; masking by
    absolute slot positions (kpos, per request). Materialized [T, Smax]
    logits — used for decode (T == 1) and small chunked-prefill steps."""
    b, h, t, hd = q.shape
    k, v = cache.k, cache.v
    hkv, smax = k.shape[1], k.shape[2]
    group = h // hkv
    qg = q.reshape(b, hkv, group, t, hd)
    logits = jnp.einsum("bkgtd,bksd->bkgts", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (hd ** -0.5)
    kpos = cache.kpos[:, None, :]                    # [B, 1, Smax]
    qpos = qpos0[:, None, None] + jnp.arange(t)[None, :, None]  # [B, T, 1]
    mask = kpos >= 0
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgts,bksd->bkgtd", p, v.astype(jnp.float32))
    return o.reshape(b, h, t, hd).astype(q.dtype)


def _ring_update(cache: KVCache, k_new, v_new):
    """Write t new timesteps into the ring buffer, per-request offsets.
    k_new [B, Hkv, t, hd]."""
    t = k_new.shape[2]
    smax = cache.k.shape[2]
    pos = cache.length[:, None] + jnp.arange(t)[None, :]   # [B, t]
    slots = pos % smax

    kc = jax.vmap(lambda kb, kn, slb: kb.at[:, slb].set(
        kn.astype(kb.dtype)))(cache.k, k_new, slots)
    vc = jax.vmap(lambda vb, vn, slb: vb.at[:, slb].set(
        vn.astype(vb.dtype)))(cache.v, v_new, slots)
    kpos = jax.vmap(lambda pb, slb, pr: pb.at[slb].set(
        pr.astype(jnp.int32)))(cache.kpos, slots, pos)
    return KVCache(kc, vc, cache.length + t, kpos)


# ------------------------------------------------------------- full layer
def attention(params, x, cfg, *, positions, causal=True, window=None,
              cache: Optional[KVCache] = None, kv_input=None,
              mode: str = "train"):
    """x [B, T, D]. kv_input: cross-attention source (defaults to x).

    mode: "train" (no cache) | "prefill" (compute via the standard path,
    then write the KV tail into the ring cache) | "decode" (ring update +
    attention against the cache). Returns (out [B, T, D], new_cache|None).
    """
    b, t, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    src = x if kv_input is None else kv_input

    q = dense(params["wq"], x).reshape(b, t, hq, hd)
    k = dense(params["wk"], src).reshape(b, src.shape[1], hkv, hd)
    v = dense(params["wv"], src).reshape(b, src.shape[1], hkv, hd)

    if positions is not None:                   # rope (self-attention only)
        q = rope(q, positions, cfg.rope_theta)
        kpos = positions if cache is None else (
            cache.length[:, None] + jnp.arange(src.shape[1])[None, :])
        k = rope(k, kpos, cfg.rope_theta)

    q = q.transpose(0, 2, 1, 3)                 # [B, H, T, hd]
    k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)

    new_cache = None
    if cache is not None and mode == "prefill":
        # attention over the fresh k/v (memory-bounded chunked path), then
        # persist the last Smax timesteps into the ring with correct
        # absolute positions (older ones could never be attended again).
        o = attention_inner(q, k, v, causal=causal, window=window,
                            impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                            gqa_expand=cfg.gqa_expand)
        smax = cache.k.shape[2]
        tail = min(smax, t)
        skipped = t - tail
        cache_adv = cache._replace(length=cache.length + skipped)
        new_cache = _ring_update(cache_adv, k[:, :, skipped:],
                                 v[:, :, skipped:])
    elif cache is not None:                     # decode / small chunk
        new_cache = _ring_update(cache, k, v)
        o = _attn_cache(q, new_cache, cache.length, cfg,
                        causal=causal, window=window)
    else:
        o = attention_inner(q, k, v, causal=causal, window=window,
                            impl=cfg.attn_impl, chunk=cfg.attn_chunk,
                            gqa_expand=cfg.gqa_expand)

    out = o.transpose(0, 2, 1, 3).reshape(b, t, hq * hd)
    return dense(params["wo"], out), new_cache
