"""Hymba hybrid block — *parallel* attention + Mamba(SSD) heads per layer.

Per the paper (arXiv:2411.13676): within each layer the input feeds both an
attention branch and an SSM branch simultaneously; per-branch outputs are
normalized and averaged before the output projection. Most layers use
sliding-window attention; `global_layers` (first/middle/last) use full
attention. 128 learned meta tokens are prepended to the sequence.

For decode, the layer carries both a (windowed) KV cache and the O(1) SSM
state — the combination that makes long_500k decoding sub-quadratic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import attention, init_attention
from repro.models.layers import dense, init_dense, rmsnorm, init_rmsnorm
from repro.models.ssm import ssd_chunked, ssd_decode_step


def init_hymba_block(key, cfg):
    d = cfg.d_model
    s = cfg.ssm
    nh = s.n_heads or d // s.head_dim
    p_dim = s.head_dim
    n = s.state_dim
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    ks = jax.random.split(key, 8)
    return {
        "attn": init_attention(ks[0], cfg),
        "ssm": {
            "w_x": init_dense(ks[1], d, nh * p_dim, dt),
            "w_z": init_dense(ks[2], d, nh * p_dim, dt),
            "w_b": init_dense(ks[3], d, nh * n, dt),
            "w_c": init_dense(ks[4], d, nh * n, dt),
            "w_dt": init_dense(ks[5], d, nh, dt),
            "dt_bias": jnp.zeros((nh,), jnp.float32),
            "a_log": jnp.zeros((nh,), jnp.float32),
            "d_skip": jnp.ones((nh,), jnp.float32),
            "w_out": init_dense(ks[6], nh * p_dim, d, dt,
                                scale=(nh * p_dim) ** -0.5
                                / (2 * cfg.n_layers) ** 0.5),
        },
        "norm_attn": init_rmsnorm(d, dt),
        "norm_ssm": init_rmsnorm(d, dt),
    }


def _ssm_branch(p, x, cfg, *, state=None, decode=False):
    b, t, d = x.shape
    s = cfg.ssm
    nh = s.n_heads or d // s.head_dim
    pd, n = s.head_dim, s.state_dim

    xh = dense(p["w_x"], x).reshape(b, t, nh, pd)
    z = jax.nn.silu(dense(p["w_z"], x)).reshape(b, t, nh, pd)
    bm = dense(p["w_b"], x).reshape(b, t, nh, n)
    cm = dense(p["w_c"], x).reshape(b, t, nh, n)
    dt_ = jax.nn.softplus(
        dense(p["w_dt"], x).astype(jnp.float32)
        + p["dt_bias"][None, None])                      # [B, T, H]

    if decode:
        assert t == 1
        y, s_new = ssd_decode_step(
            state, xh[:, 0], dt_[:, 0], p["a_log"], bm[:, 0], cm[:, 0])
        y = y[:, None]                                   # [B, 1, H, P]
    else:
        y, s_new = ssd_chunked(xh, dt_, p["a_log"], bm, cm,
                               h0=state, chunk=s.chunk)

    y = y + p["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = (y.astype(x.dtype) * z).reshape(b, t, nh * pd)
    return dense(p["w_out"], y), s_new


def hymba_block(p, x, cfg, *, positions, is_global: bool, cache=None,
                ssm_state=None, mode: str = "train"):
    """Parallel attn + SSM. is_global is a *static* bool — the stack groups
    layers into homogeneous segments so each scan sees one attention kind
    (global segments carry full-length caches, local ones window-sized
    rings). Returns (out, new_kv_cache, new_ssm_state)."""
    window = None if is_global else cfg.sliding_window
    attn_out, new_cache = attention(p["attn"], x, cfg, positions=positions,
                                    causal=True, window=window, cache=cache,
                                    mode=mode)
    ssm_out, new_state = _ssm_branch(p["ssm"], x, cfg, state=ssm_state,
                                     decode=(mode == "decode"))

    out = 0.5 * (rmsnorm(p["norm_attn"], attn_out, cfg.norm_eps)
                 + rmsnorm(p["norm_ssm"], ssm_out, cfg.norm_eps))
    return out, new_cache, new_state
