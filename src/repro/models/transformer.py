"""Composable decoder/encoder stack covering all ten assigned architectures.

The stack is a list of *segments* — runs of consecutive layers with identical
block structure. Each segment lowers to ONE `lax.scan` over stacked per-layer
params (+ remat), so a 94-layer MoE compiles to compact HLO; heterogeneous
architectures (hymba's 3 global-attention layers among 29 sliding-window
ones) become alternating segments instead of traced per-layer branches.

Layer kinds:
  attn   — GQA attention (optional SWA / qkv-bias) + SwiGLU MLP
  moe    — GQA attention + MoE FFN (optional Arctic dense-parallel branch)
  rwkv   — RWKV6 time-mix + channel-mix (attention-free)
  hymba  — parallel attention+SSM heads + SwiGLU MLP
  enc    — bidirectional attention + SwiGLU (encoder)
  xdec   — causal self-attention + cross-attention + SwiGLU (decoder)

Streaming state (KV ring caches / SSM states / token-shift tails) is stacked
per segment with the same layout as the params, so decode steps scan with
(params, state) as xs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (
    KVCache,
    attention,
    init_attention,
    init_kv_cache,
)
from repro.models.hymba import hymba_block, init_hymba_block
from repro.models.layers import (
    dtype_of,
    init_embedding,
    init_rmsnorm,
    init_swiglu,
    rmsnorm,
    swiglu,
)
from repro.models.moe import init_moe, moe_layer
from repro.models.rwkv6 import (
    init_rwkv_block,
    rwkv_channel_mix,
    rwkv_time_mix,
)


@dataclass(frozen=True)
class Segment:
    kind: str
    n_layers: int
    is_global: bool = True    # full attention (False -> cfg.sliding_window)


# --------------------------------------------------------------- planning
def plan_segments(cfg) -> list[Segment]:
    fam = cfg.family
    L = cfg.n_layers
    if fam == "ssm":
        return [Segment("rwkv", L)]
    if fam == "moe":
        return [Segment("moe", L, is_global=cfg.sliding_window is None)]
    if fam == "hybrid":
        segs: list[Segment] = []
        glob = set(cfg.global_layers)
        i = 0
        while i < L:
            g = i in glob
            j = i
            while j < L and (j in glob) == g:
                j += 1
            segs.append(Segment("hymba", j - i, is_global=g))
            i = j
        return segs
    # dense / vlm / audio-decoder
    return [Segment("attn", L, is_global=cfg.sliding_window is None)]


def plan_encoder_segments(cfg) -> list[Segment]:
    return [Segment("enc", cfg.enc_layers)] if cfg.is_encdec else []


# ------------------------------------------------------------------- init
def _init_layer(key, cfg, kind: str):
    dt = dtype_of(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": init_rmsnorm(d, dt)}
    if kind in ("attn", "enc", "xdec", "moe"):
        p["attn"] = init_attention(ks[0], cfg)
    if kind == "xdec":
        p["normx"] = init_rmsnorm(d, dt)
        p["xattn"] = init_attention(ks[1], cfg, cross=True)
    if kind == "hymba":
        p["hymba"] = init_hymba_block(ks[0], cfg)
    if kind == "rwkv":
        p["rwkv"] = init_rwkv_block(ks[0], cfg)
        p["norm2"] = init_rmsnorm(d, dt)
        return p
    p["norm2"] = init_rmsnorm(d, dt)
    if kind == "moe":
        p["moe"] = init_moe(ks[2], cfg)
    else:
        p["mlp"] = init_swiglu(ks[3], d, cfg.d_ff, dt)
    return p


def init_params(cfg, key):
    dt = dtype_of(cfg.param_dtype)
    segs = plan_segments(cfg)
    keys = jax.random.split(key, 8)

    def stack_init(seg_key, seg, kind):
        lkeys = jax.random.split(seg_key, seg.n_layers)
        return jax.vmap(lambda k: _init_layer(k, cfg, kind))(lkeys)

    params: dict[str, Any] = {
        "embed": init_embedding(keys[0], cfg.vocab, cfg.d_model, dt),
        "segments": [
            stack_init(jax.random.fold_in(keys[1], i), s, s.kind)
            for i, s in enumerate(segs)
        ],
        "final_norm": init_rmsnorm(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        w = jax.random.normal(
            keys[2], (cfg.d_model, cfg.vocab), jnp.float32) * 0.02
        params["lm_head"] = {"w": w.astype(dt)}
    if cfg.n_prefix_tokens:
        params["prefix"] = (jax.random.normal(
            keys[3], (cfg.n_prefix_tokens, cfg.d_model), jnp.float32)
            * 0.02).astype(dt)
    if cfg.is_encdec:
        esegs = plan_encoder_segments(cfg)
        params["enc_segments"] = [
            stack_init(jax.random.fold_in(keys[4], i), s, s.kind)
            for i, s in enumerate(esegs)
        ]
        params["enc_final_norm"] = init_rmsnorm(cfg.d_model, dt)
    return params


# ------------------------------------------------------------ layer apply
def _apply_layer(kind: str, lp, x, cfg, *, positions, is_global, state,
                 mode, enc_out):
    """Returns (x, new_state, aux). state/new_state: per-layer pytree."""
    window = None if is_global else cfg.sliding_window
    aux = {}
    if kind == "rwkv":
        st = state or {"tm": None, "cm": None}
        h, tm_state = rwkv_time_mix(
            lp["rwkv"]["tm"], rmsnorm(lp["norm1"], x, cfg.norm_eps), cfg,
            state=st["tm"], impl=cfg.attn_impl if cfg.attn_impl == "ref"
            else "chunked")
        x = x + h
        h, cm_state = rwkv_channel_mix(
            lp["rwkv"]["cm"], rmsnorm(lp["norm2"], x, cfg.norm_eps),
            state=st["cm"])
        x = x + h
        return x, {"tm": tm_state, "cm": cm_state}, aux

    if kind == "hymba":
        st = state or {"kv": None, "ssm": None}
        h, kv, ssm = hymba_block(
            lp["hymba"], rmsnorm(lp["norm1"], x, cfg.norm_eps), cfg,
            positions=positions, is_global=is_global, cache=st["kv"],
            ssm_state=st["ssm"], mode=mode)
        x = x + h
        x = x + swiglu(lp["mlp"], rmsnorm(lp["norm2"], x, cfg.norm_eps))
        return x, {"kv": kv, "ssm": ssm}, aux

    # attention families
    causal = kind != "enc"
    cache = None if state is None else state.get("kv")
    if (kind == "attn" and cfg.tp_shard_map and mode == "train"
            and cache is None):
        from repro.distributed.context import get_mesh

        mesh = get_mesh()
        if mesh is not None and "model" in mesh.axis_names \
                and cfg.n_heads % dict(zip(mesh.axis_names,
                                           mesh.devices.shape))["model"] == 0:
            from repro.models.block_sharded import attn_mlp_block_sharded

            x = attn_mlp_block_sharded(lp, x, cfg, positions=positions,
                                       window=window, mesh=mesh)
            return x, None, {}
    h, kv = attention(lp["attn"], rmsnorm(lp["norm1"], x, cfg.norm_eps),
                      cfg, positions=positions, causal=causal,
                      window=window, cache=cache, mode=mode)
    x = x + h
    new_state = None if state is None else {"kv": kv}

    if kind == "xdec":
        # cross-attention: kv from encoder output (no rope, non-causal)
        h, _ = attention(lp["xattn"], rmsnorm(lp["normx"], x, cfg.norm_eps),
                         cfg, positions=None, causal=False,
                         kv_input=enc_out, mode="train")
        x = x + h

    hn = rmsnorm(lp["norm2"], x, cfg.norm_eps)
    if kind == "moe":
        mesh = None
        if cfg.moe_impl.startswith("shard_map"):
            from repro.distributed.context import get_mesh

            mesh = get_mesh()
        if mesh is not None:
            from repro.models.moe_sharded import moe_layer_sharded

            h, aux = moe_layer_sharded(lp["moe"], hn, cfg, mesh)
        else:
            h, aux = moe_layer(lp["moe"], hn, cfg)
    else:
        h = swiglu(lp["mlp"], hn)
    x = x + h
    return x, new_state, aux


_ZERO_AUX = {"load_balance_loss": 0.0, "router_z_loss": 0.0,
             "overflow_fraction": 0.0}


def _sp_constraint(x, cfg):
    """Megatron-style sequence parallelism: between blocks the residual
    stream is sharded over (T -> model); GSPMD converts each block's
    all-reduce into reduce-scatter + all-gather (§Perf iteration 7)."""
    if not cfg.seq_parallel or x.shape[1] % 16:
        return x
    from repro.distributed.context import get_mesh

    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = dax if len(dax) > 1 else (dax[0] if dax else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bspec, "model", None)))


def run_segment(seg: Segment, segp, x, cfg, *, positions, state=None,
                mode="train", enc_out=None):
    """Scan a homogeneous segment. state: stacked per-layer pytree or None.
    Returns (x, new_state, aux-summed-over-layers)."""

    def body(carry, xs):
        xx = carry
        lp, lstate = xs
        xx = _sp_constraint(xx, cfg)
        xx, new_lstate, aux = _apply_layer(
            seg.kind, lp, xx, cfg, positions=positions,
            is_global=seg.is_global, state=lstate, mode=mode,
            enc_out=enc_out)
        if not aux:
            aux = dict(_ZERO_AUX)
        aux = {k: jnp.asarray(v, jnp.float32) for k, v in aux.items()}
        return xx, (new_lstate, aux)

    if cfg.remat:
        body = jax.checkpoint(body)

    if cfg.use_scan and seg.n_layers > 1:
        x, (new_state, auxs) = jax.lax.scan(body, x, (segp, state))
        aux = jax.tree_util.tree_map(lambda a: jnp.sum(a, axis=0), auxs)
        return x, new_state, aux
    # unrolled (singleton segments / debugging)
    new_states = []
    aux_tot = {k: jnp.float32(0) for k in _ZERO_AUX}
    for i in range(seg.n_layers):
        lp = jax.tree_util.tree_map(lambda a: a[i], segp)
        lstate = (None if state is None
                  else jax.tree_util.tree_map(lambda a: a[i], state))
        x, (new_lstate, aux) = body(x, (lp, lstate))
        new_states.append(new_lstate)
        aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
    if new_states and new_states[0] is not None:
        new_state = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *new_states)
    else:
        new_state = None
    return x, new_state, aux_tot


# --------------------------------------------------------------- forward
def forward_hidden(params, x, cfg, *, positions, states=None, mode="train",
                   enc_out=None, segments=None):
    """x [B, T, D] embeddings -> (hidden [B, T, D], new_states, aux)."""
    segs = segments if segments is not None else plan_segments(cfg)
    new_states = []
    aux_tot = {k: jnp.float32(0) for k in _ZERO_AUX}
    for i, (seg, segp) in enumerate(zip(segs, params["segments"])):
        st = None if states is None else states[i]
        x, ns, aux = run_segment(seg, segp, x, cfg, positions=positions,
                                 state=st, mode=mode, enc_out=enc_out)
        new_states.append(ns)
        for k in aux_tot:
            aux_tot[k] = aux_tot[k] + jnp.asarray(aux.get(k, 0.0),
                                                  jnp.float32)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return x, (new_states if states is not None else None), aux_tot


def run_encoder(params, src_embeds, cfg):
    segs = plan_encoder_segments(cfg)
    x = src_embeds
    pos = jnp.arange(src_embeds.shape[1])
    for seg, segp in zip(segs, params["enc_segments"]):
        x, _, _ = run_segment(seg, segp, x, cfg, positions=pos, mode="train")
    return rmsnorm(params["enc_final_norm"], x, cfg.norm_eps)


def logits_head(params, hidden, cfg):
    if cfg.tie_embeddings:
        w = params["embed"]["table"].T
    else:
        w = params["lm_head"]["w"]
    return (hidden @ w).astype(jnp.float32)


# ------------------------------------------------------- streaming states
def init_segment_state(seg: Segment, cfg, batch: int, max_len: int,
                       dtype=jnp.bfloat16):
    """Stacked streaming state for one segment (decode/serving)."""
    hd = cfg.hd

    def per_layer(_):
        if seg.kind == "rwkv":
            h = cfg.d_model // hd
            return {
                "tm": {"last": jnp.zeros((batch, 1, cfg.d_model), dtype),
                       "s": jnp.zeros((batch, h, hd, hd), jnp.float32)},
                "cm": {"last": jnp.zeros((batch, 1, cfg.d_model), dtype)},
            }
        smax = max_len
        if not seg.is_global and cfg.sliding_window is not None:
            smax = min(max_len, cfg.sliding_window)
        kv_dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
                 "float8_e4m3fn": jnp.float8_e4m3fn}[cfg.kv_cache_dtype]
        if cfg.kv_cache_dtype == "bfloat16":
            kv_dt = dtype  # follow param dtype (fp32 in tests)
        kv = init_kv_cache(batch, cfg.n_kv_heads, smax, hd, kv_dt)
        if seg.kind == "hymba":
            s = cfg.ssm
            nh = s.n_heads or cfg.d_model // s.head_dim
            return {"kv": kv,
                    "ssm": jnp.zeros((batch, nh, s.head_dim, s.state_dim),
                                     jnp.float32)}
        return {"kv": kv}

    states = [per_layer(i) for i in range(seg.n_layers)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def init_states(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    return [init_segment_state(s, cfg, batch, max_len, dtype)
            for s in plan_segments(cfg)]
