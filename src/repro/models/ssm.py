"""Chunked selective-state-space machinery (Mamba2 / SSD-style), pure JAX.

Recurrence per head h with state S ∈ R^{P×N}:

    S_t = a_t · S_{t-1} + dt_t · x_t ⊗ B_t          (a_t = exp(-dt_t·exp(A_log)))
    y_t = S_t · C_t + D_skip · x_t

Chunked-scan formulation (the TPU-native rethink of the CUDA selective-scan
kernel): scan over chunks of length L carrying S; within a chunk, all
pairwise decay products are expressed through cumulative log-decays whose
differences are <= 0, so everything is numerically safe without max-shifts:

    cum_t = Σ_{j<=t} log a_j
    intra: y[t] += Σ_{i<=t} e^{cum_t - cum_i} (C_t·B_i) dt_i x_i
    state: y[t] += e^{cum_t} C_t · S0 ;  S' = e^{cum_L} S0 + Σ_i e^{cum_L-cum_i} dt_i x_i ⊗ B_i

Used by the hymba hybrid architecture (ssm_state=16). The O(1)-state decode
step makes long_500k tractable.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunked(x, dt, a_log, bmat, cmat, *, h0=None, chunk: int = 256):
    """x [B, T, H, P]; dt [B, T, H] (>0, post-softplus); a_log [H];
    bmat, cmat [B, T, H, N]. Returns (y [B, T, H, P] f32, S [B, H, P, N])."""
    b, t, h, p = x.shape
    n = bmat.shape[-1]
    L = min(chunk, t)
    while t % L:
        L //= 2
    nc = t // L

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = bmat.astype(jnp.float32)
    cf = cmat.astype(jnp.float32)
    loga = -dtf * jnp.exp(a_log.astype(jnp.float32))[None, None, :]  # [B,T,H]

    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)
    else:
        h0 = h0.astype(jnp.float32)

    resh = lambda z: z.reshape(b, nc, L, *z.shape[2:]).swapaxes(0, 1)
    xs = (resh(xf), resh(dtf), resh(bf), resh(cf), resh(loga))

    def per_chunk(S, xs_c):
        xc, dtc, bc, cc, lac = xs_c          # [B, L, ...]
        cum = jnp.cumsum(lac, axis=1)        # [B, L, H] decreasing
        # intra-chunk: y[t] = Σ_{i<=t} e^{cum_t-cum_i} (C_t·B_i) dt_i x_i
        g = jnp.einsum("bthn,bihn->btih", cc, bc)          # [B, L, L, H]
        m = jnp.exp(cum[:, :, None, :] - cum[:, None, :, :])
        tri = (jnp.arange(L)[:, None] >= jnp.arange(L)[None, :])
        w = jnp.where(tri[None, :, :, None], m * g, 0.0)
        y = jnp.einsum("btih,bih,bihp->bthp", w, dtc, xc)
        # state term
        y = y + jnp.einsum("bthn,bth,bhpn->bthp", cc, jnp.exp(cum), S)
        # state update
        tot = cum[:, -1]                                    # [B, H]
        decay_i = jnp.exp(tot[:, None, :] - cum)            # [B, L, H]
        S_new = (jnp.exp(tot)[:, :, None, None] * S
                 + jnp.einsum("blh,blh,blhp,blhn->bhpn",
                              decay_i, dtc, xc, bc))
        return S_new, y

    S_fin, ys = jax.lax.scan(per_chunk, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, t, h, p)
    return y, S_fin


def ssd_ref(x, dt, a_log, bmat, cmat, *, h0=None):
    """Naive per-step scan oracle."""
    b, t, h, p = x.shape
    n = bmat.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), jnp.float32)

    a = jnp.exp(-dt.astype(jnp.float32)
                * jnp.exp(a_log.astype(jnp.float32))[None, None, :])

    def step(S, xs):
        xt, dtt, bt, ct, at = xs
        S = at[:, :, None, None] * S + jnp.einsum(
            "bh,bhp,bhn->bhpn", dtt, xt, bt)
        y = jnp.einsum("bhpn,bhn->bhp", S, ct)
        return S, y

    xs = (x.astype(jnp.float32).swapaxes(0, 1),
          dt.astype(jnp.float32).swapaxes(0, 1),
          bmat.astype(jnp.float32).swapaxes(0, 1),
          cmat.astype(jnp.float32).swapaxes(0, 1),
          a.swapaxes(0, 1))
    S_fin, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), S_fin


def ssd_decode_step(S, x, dt, a_log, bmat, cmat):
    """One-token step. x [B, H, P]; dt [B, H]; bmat/cmat [B, H, N];
    S [B, H, P, N]. Returns (y [B, H, P], S')."""
    a = jnp.exp(-dt.astype(jnp.float32)
                * jnp.exp(a_log.astype(jnp.float32))[None, :])
    S = (a[:, :, None, None] * S.astype(jnp.float32)
         + jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(jnp.float32),
                      x.astype(jnp.float32), bmat.astype(jnp.float32)))
    y = jnp.einsum("bhpn,bhn->bhp", S, cmat.astype(jnp.float32))
    return y, S
