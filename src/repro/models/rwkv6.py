"""RWKV6 "Finch" block (attention-free, data-dependent decay).

Faithful structure per layer:
  time-mix: token-shift lerps -> r, k, v, g projections; decay
            w_t = exp(-exp(w0 + tanh(x_w @ A) @ B)) (the low-rank
            data-dependent decay that defines Finch); WKV recurrence;
            per-head groupnorm; silu(g) gate; output projection.
  channel-mix: token-shift lerp; k = relu(x @ Wk)^2; out = (k @ Wv).

Sequence mixing runs through one of:
  * kernels/wkv6 Pallas kernel           (TPU path)
  * wkv6_chunked_jnp below               (default lowering/dry-run path —
    same chunked math as the kernel, scan over chunks, stable exponents)
  * kernels/wkv6/ref.py per-step scan    (tiny tests)

State is O(H·D²) per layer — long_500k decode is a constant-memory step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, init_dense

DECAY_LORA = 64


def init_rwkv_block(key, cfg):
    d = cfg.d_model
    dt = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[cfg.param_dtype]
    hd = cfg.hd
    h = d // hd
    ks = jax.random.split(key, 12)
    lora = min(DECAY_LORA, d)

    def mu(k):
        return jax.random.uniform(k, (d,), jnp.float32).astype(dt)

    return {
        "tm": {  # time-mix
            "mu_r": mu(ks[0]), "mu_k": mu(ks[1]), "mu_v": mu(ks[2]),
            "mu_w": mu(ks[3]), "mu_g": mu(ks[4]),
            "wr": init_dense(ks[5], d, d, dt),
            "wk": init_dense(ks[6], d, d, dt),
            "wv": init_dense(ks[7], d, d, dt),
            "wg": init_dense(ks[8], d, d, dt),
            "wo": init_dense(ks[9], d, d, dt, scale=d ** -0.5
                             / (2 * cfg.n_layers) ** 0.5),
            "w0": jnp.full((d,), -1.0, dt),     # base decay logit
            "w_lora_a": init_dense(ks[10], d, lora, dt),
            "w_lora_b": init_dense(ks[11], lora, d, dt,
                                   scale=lora ** -0.5 * 0.1),
            "u": (jax.random.normal(ks[0], (h, hd), jnp.float32) * 0.3
                  ).astype(dt),
            "ln_scale": jnp.ones((d,), dt),     # per-head groupnorm scale
        },
        "cm": {  # channel-mix
            "mu": mu(ks[1]),
            "wk": init_dense(ks[2], d, cfg.d_ff, dt),
            "wv": init_dense(ks[3], cfg.d_ff, d, dt,
                             scale=cfg.d_ff ** -0.5),
        },
    }


def _token_shift(x, last=None):
    """shift right by one along T; `last` [B, 1, D] fills position 0."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([last, x[:, :-1]], axis=1)


def wkv6_chunked_jnp(r, k, v, w, u, *, s0=None, chunk: int = 64):
    """Same chunked math as the Pallas kernel, vectorized over [B, H].

    r/k/v/w [B, H, T, D]; u [H, D] -> (o [B,H,T,D] f32, s [B,H,D,D] f32).
    """
    b, h, t, d = r.shape
    L = min(chunk, t)
    while t % L:
        L //= 2
    nc = t // L
    rf, kf, vf, wf = (z.astype(jnp.float32) for z in (r, k, v, w))
    uf = u.astype(jnp.float32)
    if s0 is None:
        s0 = jnp.zeros((b, h, d, d), jnp.float32)

    resh = lambda z: z.reshape(b, h, nc, L, d).transpose(2, 0, 1, 3, 4)
    xs = (resh(rf), resh(kf), resh(vf), resh(wf))

    tri = jnp.arange(L)[:, None] > jnp.arange(L)[None, :]

    def per_chunk(S, xs_c):
        rc, kc, vc, wc = xs_c                       # [B, H, L, D]
        lw = jnp.log(wc)
        s_incl = jnp.cumsum(lw, axis=2)
        s_excl = s_incl - lw
        q = rc * jnp.exp(s_excl)
        o = jnp.einsum("bhld,bhde->bhle", q, S)
        # intra: A[t,i] = Σ_d r[t,d] k[i,d] e^{s_excl[t,d]-s_incl[i,d]}
        expd = jnp.exp(s_excl[:, :, :, None, :] - s_incl[:, :, None, :, :])
        a = jnp.einsum("bhtd,bhid,bhtid->bhti", rc, kc, expd)
        a = jnp.where(tri[None, None], a, 0.0)
        diag = jnp.sum(rc * kc * uf[None, :, None, :], axis=-1)
        o = o + jnp.einsum("bhti,bhid->bhtd", a, vc) \
            + diag[..., None] * vc
        tot = s_incl[:, :, -1]                      # [B, H, D]
        k_dec = kc * jnp.exp(tot[:, :, None, :] - s_incl)
        S = (jnp.exp(tot)[:, :, :, None] * S
             + jnp.einsum("bhlk,bhlv->bhkv", k_dec, vc))
        return S, o

    S_fin, os_ = jax.lax.scan(per_chunk, s0, xs)
    o = os_.transpose(1, 2, 0, 3, 4).reshape(b, h, t, d)
    return o, S_fin


def rwkv_time_mix(p, x, cfg, *, state=None, impl="chunked"):
    """x [B, T, D]. state: dict(last [B,1,D], s [B,H,D,D]) for streaming.
    Returns (out [B, T, D], new_state)."""
    b, t, d = x.shape
    hd = cfg.hd
    h = d // hd

    last = None if state is None else state["last"]
    xs = _token_shift(x, last)

    def mix(mu):
        return x + (xs - x) * mu[None, None, :]

    r = dense(p["wr"], mix(p["mu_r"]))
    k = dense(p["wk"], mix(p["mu_k"]))
    v = dense(p["wv"], mix(p["mu_v"]))
    g = jax.nn.silu(dense(p["wg"], mix(p["mu_g"])))
    xw = mix(p["mu_w"])
    wlog = (p["w0"].astype(jnp.float32)[None, None]
            + dense(p["w_lora_b"],
                    jnp.tanh(dense(p["w_lora_a"], xw))).astype(jnp.float32))
    w = jnp.exp(-jnp.exp(wlog))                     # (0,1) data-dependent

    split = lambda z: z.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    rh, kh, vh, wh = split(r), split(k), split(v), split(w.astype(x.dtype))
    u = p["u"].astype(jnp.float32)

    s0 = None if state is None else state["s"]
    if impl == "pallas":
        from repro.kernels.wkv6.ops import wkv6

        assert s0 is None, "kernel path starts from zero state"
        o, s_fin = wkv6(rh, kh, vh, wh, u)
    elif impl == "ref":
        from repro.kernels.wkv6.ref import wkv6_ref

        o, s_fin = wkv6_ref(rh, kh, vh, wh, u, s0=s0)
    else:
        o, s_fin = wkv6_chunked_jnp(rh, kh, vh, wh, u, s0=s0)

    # per-head groupnorm
    mean = jnp.mean(o, axis=-1, keepdims=True)
    var = jnp.var(o, axis=-1, keepdims=True)
    o = (o - mean) * jax.lax.rsqrt(var + 64e-5)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    o = o * p["ln_scale"].astype(jnp.float32)[None, None]
    o = o.astype(x.dtype) * g

    out = dense(p["wo"], o)
    new_state = {"last": x[:, -1:], "s": s_fin}
    return out, new_state


def rwkv_channel_mix(p, x, *, state=None):
    last = None if state is None else state["last"]
    xs = _token_shift(x, last)
    xm = x + (xs - x) * p["mu"][None, None, :]
    k = jnp.square(jax.nn.relu(dense(p["wk"], xm)))
    out = dense(p["wv"], k)
    return out, {"last": x[:, -1:]}
