"""shard_map Megatron-SP transformer block (§Perf iteration 10).

GSPMD-driven sequence parallelism regressed 1.9× (EXPERIMENTS.md iteration
7): the chunked-attention inner map re-gathers a T-sharded operand per
chunk. This block pins the schedule by hand, the same way moe_sharded.py
does for EP:

  residual stream x: [B, T/msz, D]   (T sharded over model between blocks)
  1. all_gather(model, T)   -> x_full [B, T, D]          (0.47 GiB·15/16)
  2. norm1; qkv with column-sharded weights -> local q-head subset
     (kv replicated when Hkv doesn't divide; expanded+sliced locally)
  3. chunked attention — entirely local (head-subset)
  4. out-projection row-sharded -> partial [B, T, D]
  5. reduce_scatter(model, T)  + residual add             (0.47 GiB·15/16)
  6. same AG/RS pair around the SwiGLU MLP

vs the pjit baseline's 2 all-reduces (= 2×bytes each): the napkin says
~2× less wire per layer plus T-sharded activations between blocks.

Weight layouts match distributed/sharding.py's TP rules, so the same
checkpoint serves both paths. Used when cfg.tp_shard_map is set and heads
divide the model axis (dense/vlm families).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.utils.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.attention import attention_inner
from repro.models.layers import rmsnorm, rope


def attn_mlp_block_sharded(lp, x, cfg, *, positions, window, mesh):
    """One pre-norm attention+SwiGLU layer under manual SP.

    x [B, T, D] logically T-sharded over model (in_spec pins it). Returns
    the same layout. lp: the standard layer params (norm1/attn/norm2/mlp).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msz = sizes.get("model", 1)
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bspec = dax if len(dax) > 1 else (dax[0] if dax else None)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    assert hq % msz == 0, "tp_shard_map needs q-heads % model == 0"
    h_loc = hq // msz
    kv_sharded = hkv % msz == 0

    def fn(xs, n1, wq, wk, wv, wo, n2, wg, wu, wdn):
        b = xs.shape[0]
        # ---- SP: gather the full sequence ----
        xf = jax.lax.all_gather(xs, "model", axis=1, tiled=True)  # [B,T,D]
        t = xf.shape[1]
        h = rmsnorm({"scale": n1}, xf, cfg.norm_eps)

        q = (h @ wq).reshape(b, t, h_loc, hd)
        k = (h @ wk).reshape(b, t, -1, hd)
        v = (h @ wv).reshape(b, t, -1, hd)
        q = rope(q, positions[:t], cfg.rope_theta)
        k = rope(k, positions[:t], cfg.rope_theta)
        q = q.transpose(0, 2, 1, 3)
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        if not kv_sharded:
            # kv replicated: expand to all q heads, slice this rank's span
            group = hq // hkv
            midx = jax.lax.axis_index("model")
            k = jnp.repeat(k, group, axis=1)
            v = jnp.repeat(v, group, axis=1)
            k = jax.lax.dynamic_slice_in_dim(k, midx * h_loc, h_loc, 1)
            v = jax.lax.dynamic_slice_in_dim(v, midx * h_loc, h_loc, 1)
        o = attention_inner(q, k, v, causal=True, window=window,
                            impl="chunked", chunk=cfg.attn_chunk)
        o = o.transpose(0, 2, 1, 3).reshape(b, t, h_loc * hd)
        part = o @ wo                                   # partial over heads
        # ---- SP: reduce_scatter back to T-shards + residual ----
        att = jax.lax.psum_scatter(part, "model", scatter_dimension=1,
                                   tiled=True)
        xs = xs + att.astype(xs.dtype)

        # ---- MLP with the same AG/RS pair ----
        xf2 = jax.lax.all_gather(xs, "model", axis=1, tiled=True)
        h2 = rmsnorm({"scale": n2}, xf2, cfg.norm_eps)
        act = jax.nn.silu(h2 @ wg) * (h2 @ wu)
        part2 = act @ wdn
        mlp = jax.lax.psum_scatter(part2, "model", scatter_dimension=1,
                                   tiled=True)
        return xs + mlp.astype(xs.dtype)

    kv_spec = P(None, "model") if kv_sharded else P(None, None)
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, "model", None),   # x: T-sharded
                  P(None),                   # norm1 scale
                  P(None, "model"),          # wq col-sharded (heads)
                  kv_spec, kv_spec,          # wk, wv
                  P("model", None),          # wo row-sharded
                  P(None),                   # norm2 scale
                  P(None, "model"),          # w_gate
                  P(None, "model"),          # w_up
                  P("model", None)),         # w_down
        out_specs=P(bspec, "model", None),
        check_vma=False,
    )(x, lp["norm1"]["scale"], lp["attn"]["wq"]["w"], lp["attn"]["wk"]["w"],
      lp["attn"]["wv"]["w"], lp["attn"]["wo"]["w"], lp["norm2"]["scale"],
      lp["mlp"]["w_gate"]["w"], lp["mlp"]["w_up"]["w"],
      lp["mlp"]["w_out"]["w"])
