"""shard_map expert-parallel MoE — the §Perf rewrite of the dense dispatch.

Why (measured, EXPERIMENTS.md §Perf): the pjit dense dispatch lets GSPMD
choose the collectives for the [E, C, D] scatter, and it chooses
catastrophically — 51 TB wire/step on qwen3-moe train_4k (every layer
re-gathers the expert buffer). This version pins the textbook EP schedule
explicitly:

  tokens: sharded over the data axes; replicated over model
  w_gate/w_up: [E→data, D→model, Fe]   w_out: [E→data, Fe, D→model]

  1. local top-k / sort / capacity  -> buf [E, C_loc, D_loc]
     (each model rank dispatches only its D-slice: the a2a ships D/msz)
  2. all_to_all over data           -> buf' [E_loc, dsz·C_loc, D_loc]
  3. h = buf' ·_D w_gate  (partial over D) --psum(model, bf16)--> [rows, Fe]
     silu gating local
  4. y = act · w_out      -> [rows, D_loc]  (no comms; D stays sharded)
  5. reverse all_to_all over data   -> [E, C_loc, D_loc]
  6. local gate-weighted combine -> out [N_loc, D_loc]
     --all_gather(model)--> [N_loc, D]  (residual stream is
     model-replicated elsewhere)

Napkin (qwen3 train_4k, per device per layer, fwd): 2×0.34 GiB a2a +
~3.2 GiB h/u psum + 0.5 GiB gather ≈ 4.4 GiB — vs ~540 GiB/layer measured
for the dense dispatch (≈40× predicted; dry-run confirms, EXPERIMENTS.md).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.utils.compat import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.layers import swiglu


def _local_dispatch(xd, probs, k, e, cap):
    """xd [N, Dl]; probs [N, E] -> (buf [E, cap, Dl], se, st, sg, keep,
    rank) — sorted (expert, token, gate) arrays reused by the combine."""
    n = xd.shape[0]
    gates, choice = jax.lax.top_k(probs, k)                  # [N, k]
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    flat_e = choice.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(n), k)
    flat_g = gates.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(se, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(n * k) - starts[se]
    keep = rank < cap
    slot = jnp.where(keep, rank, cap)
    buf = jnp.zeros((e, cap + 1, xd.shape[1]), xd.dtype)
    buf = buf.at[se, slot].add(xd[st])
    return buf[:, :cap], se, st, sg, keep, rank


def moe_layer_sharded(params, x, cfg, mesh):
    """Drop-in replacement for moe_layer under `mesh`. x [B, S, D] sharded
    P(data-axes, None, None), model-replicated. Requires the shard_map
    param layout (sharding.py selects it when cfg.moe_impl=='shard_map')."""
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dsz = int(np.prod([sizes[a] for a in dax])) if dax else 1
    msz = sizes.get("model", 1)
    n_loc = (b * s) // dsz
    cap = int(n_loc * k / e * m.capacity_factor) + 1
    if cfg.moe_impl == "shard_map_wg" and msz > 1:
        # rows regrouped over model: dsz·cap must split msz ways
        cap = -(-cap // msz) * msz
    dl = d // msz
    weight_gathered = cfg.moe_impl == "shard_map_wg"
    bspec = dax if len(dax) > 1 else (dax[0] if dax else None)

    def fn(x_loc, rw, wg_l, wu_l, wo_l):
        nl = x_loc.shape[0] * x_loc.shape[1]
        xf = x_loc.reshape(nl, d)
        logits = xf.astype(jnp.float32) @ rw["w"]            # [Nl, E]
        probs = jax.nn.softmax(logits, axis=-1)

        if msz > 1:
            midx = jax.lax.axis_index("model")
            xd = jax.lax.dynamic_slice_in_dim(xf, midx * dl, dl, axis=1)
        else:
            xd = xf
        buf, se, st, sg, keep, rank = _local_dispatch(xd, probs, k, e, cap)

        # ---- EP all-to-all over the data axes ----
        if dsz > 1:
            buf = jax.lax.all_to_all(buf, dax, split_axis=0, concat_axis=1,
                                     tiled=True)     # [E/dsz, dsz·cap, Dl]
        if weight_gathered and msz > 1:
            # §Perf iteration 6: row-parallel expert GEMMs. Gather this
            # layer's expert weights over model (transient, ~2×300 MiB for
            # qwen3) and regroup the dispatch rows over model via a second
            # a2a, so each model rank runs full-D GEMMs on 1/msz of the
            # rows — replacing the 2×~3.2 GiB/layer h/u psums with
            # ~0.3 GiB a2as (measured in EXPERIMENTS.md §Perf).
            wg_f = jax.lax.all_gather(wg_l, "model", axis=1, tiled=True)
            wu_f = jax.lax.all_gather(wu_l, "model", axis=1, tiled=True)
            wo_f = jax.lax.all_gather(wo_l, "model", axis=2, tiled=True)
            rows = jax.lax.all_to_all(buf, "model", split_axis=1,
                                      concat_axis=2, tiled=True)
            h = jnp.einsum("ecd,edf->ecf", rows.astype(jnp.float32),
                           wg_f.astype(jnp.float32))
            u = jnp.einsum("ecd,edf->ecf", rows.astype(jnp.float32),
                           wu_f.astype(jnp.float32))
            act = jax.nn.silu(h) * u
            y = jnp.einsum("ecf,efd->ecd", act.astype(x.dtype), wo_f)
            y = jax.lax.all_to_all(y, "model", split_axis=2, concat_axis=1,
                                   tiled=True)       # back to [.., C', Dl]
        else:
            # ---- expert GEMMs (contraction over model-sharded D) ----
            h = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                           wg_l.astype(jnp.float32))
            u = jnp.einsum("ecd,edf->ecf", buf.astype(jnp.float32),
                           wu_l.astype(jnp.float32))
            if msz > 1:
                h = jax.lax.psum(h.astype(jnp.bfloat16), "model")
                u = jax.lax.psum(u.astype(jnp.bfloat16), "model")
            act = (jax.nn.silu(h.astype(jnp.float32))
                   * u.astype(jnp.float32))
            y = jnp.einsum("ecf,efd->ecd", act.astype(x.dtype), wo_l)

        # ---- reverse a2a + local combine ----
        if dsz > 1:
            y = jax.lax.all_to_all(y, dax, split_axis=1, concat_axis=0,
                                   tiled=True)               # [E, cap, Dl]
        contrib = y[se, jnp.where(keep, rank, 0)]
        contrib = jnp.where(keep[:, None], contrib, 0.0)
        out = jnp.zeros((nl, dl), y.dtype).at[st].add(
            contrib * sg[:, None].astype(y.dtype))
        if msz > 1:
            out = jax.lax.all_gather(out, "model", axis=1, tiled=True)

        # ---- aux metrics (consistent with models/moe.py) ----
        me = jnp.mean(probs, axis=0)
        ce = jnp.bincount(se, length=e).astype(jnp.float32) / nl
        lb = e * jnp.sum(me * ce) / k
        z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
        ov = 1.0 - jnp.mean(keep.astype(jnp.float32))
        if dax:
            lb = jax.lax.pmean(lb, dax)
            z = jax.lax.pmean(z, dax)
            ov = jax.lax.pmean(ov, dax)
        aux = jnp.stack([lb, z, ov])
        return out.reshape(x_loc.shape[0], x_loc.shape[1], d), aux

    out, aux = shard_map(
        fn, mesh=mesh,
        in_specs=(P(bspec, None, None),
                  P(),                               # router replicated
                  P(bspec, "model", None),           # w_gate [E, D, Fe]
                  P(bspec, "model", None),           # w_up
                  P(bspec, None, "model")),          # w_out [E, Fe, D]
        out_specs=(P(bspec, None, None), P()),
        check_vma=False,
    )(x, params["router"], params["experts"]["w_gate"],
      params["experts"]["w_up"], params["experts"]["w_out"])

    aux_d = {"load_balance_loss": aux[0], "router_z_loss": aux[1],
             "overflow_fraction": aux[2]}
    if m.dense_parallel:
        out = out + swiglu(params["dense_mlp"], x)
    return out, aux_d
