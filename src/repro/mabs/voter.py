"""Voter model on an arbitrary contact network.

N agents, each holding one of q opinions. One *task* = one asynchronous
update (chain granularity):

  creation  — draw agent v uniformly; draw u uniformly among v's topology
              neighbors (task depth: both ids fixed at creation, so the
              dependence footprint is pure id matching).
  execution — v adopts u's opinion:  opinions[v] := opinions[u].

This is the first model written *natively* against the footprint protocol:
it declares ``task_footprint`` (R = {u}, W = {v}) and inherits the derived
``conflicts`` from MABSModel — no hand-written dependence predicate, and
window scheduling runs through the conflict kernel. Only the strict rule
(adding the v_i == v_j output and v_i == u_j anti hazards to the paper's
u_i == v_j record test) is bit-exact vs sequential execution.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.model import MABSModel
from repro.topology import Topology


@dataclass
class VoterConfig:
    n_opinions: int = 2


class VoterModel(MABSModel):
    name = "voter"

    def __init__(self, topology: Topology,
                 config: VoterConfig | None = None):
        assert int(topology.degrees.min()) >= 1, (
            "voter dynamics need every node to have a neighbor "
            "(isolated nodes would sample the -1 padding slot)")
        self.topology = topology
        self.cfg = config or VoterConfig()

    # ------------------------------------------------------------- state
    def init_state(self, rng: jax.Array):
        opinions = jax.random.randint(
            rng, (self.topology.n_nodes,), 0, self.cfg.n_opinions,
            dtype=jnp.int32)
        return {"opinions": opinions}

    # ---------------------------------------------------------- creation
    def create_tasks(self, base_key: jax.Array, start_index, count: int):
        topo = self.topology
        idx = start_index + jnp.arange(count)

        def one(i):
            k = jax.random.fold_in(base_key, i)
            kv, ku = jax.random.split(k)
            v = jax.random.randint(kv, (), 0, topo.n_nodes)
            u = topo.sample_neighbor(ku, v)
            return v.astype(jnp.int32), u.astype(jnp.int32)

        v, u = jax.vmap(one)(idx)
        return {"v": v, "u": u, "index": idx.astype(jnp.int32)}

    # -------------------------------------------------------- dependence
    def task_footprint(self, recipes):
        """R = {u} (the copied opinion), W = {v} (the updated agent)."""
        return recipes["u"][..., None], recipes["v"][..., None]

    def task_write_agents(self, recipes):
        """Writes land in row v — the sharded engine's ownership key."""
        return recipes["v"][..., None]

    def task_read_agents(self, recipes):
        """Only row u is read (row v is fully overwritten), so the halo
        each device gathers per wave is one row per owned task."""
        return recipes["u"][..., None]

    # --------------------------------------------------------- execution
    def execute_wave(self, state, recipes, mask):
        opinions = state["opinions"]
        n = self.topology.n_nodes
        new_vals = opinions[recipes["u"]]
        rows = jnp.where(mask, recipes["v"], n)  # OOB drop when inactive
        opinions = opinions.at[rows].set(
            jnp.where(mask, new_vals, 0), mode="drop")
        return {"opinions": opinions}
