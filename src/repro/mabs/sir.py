"""SIRS epidemic on a contact network (paper §4.2, generalized).

N agents on an arbitrary ``repro.topology.Topology`` (default: the paper's
ring of constant degree k, agent v connected to v±1..±k/2).
States: S=0, I=1, R=2. Per global step, each agent may advance one state:
  S->I with prob p_SI * (infected fraction of its neighbours)
  I->R with prob p_IR
  R->S with prob p_RS
using the *previous* step's states (synchronous update), realized with a
new-state buffer.

Protocol mapping (paper §4.2): the system is partitioned into M = N/s fixed
contiguous subsets of size s (chain granularity). Each global step emits
2M tasks:
  type A (compute): new_states[subset] := transition(states[nbhd(subset)])
  type B (commit):  states[subset]     := new_states[subset]
Chain order: step r = [A_0..A_{M-1}, B_0..B_{M-1}].

Dependence — with blk(i) the subset id and adjacency on the *aggregate
subset graph* (Topology.block_graph: blocks joined by any contact edge,
every block adjacent to itself; on the ring this reduces to circular block
distance <= ceil((k/2)/s)):

  paper rule (strict=False):
    B_i depends on earlier A_j  iff blk_i == blk_j
    A_i depends on earlier B_j  iff adjacent(blk_i, blk_j)
  strict rule (strict=True) adds the hazards the paper omits:
    B_i depends on earlier A_j  iff adjacent(blk_i, blk_j)   (anti: B_i
      overwrites states[blk_i] that a pending A_j still reads),
    A_i / A_j and B_i / B_j on the same subset (output hazards on
      new_states[blk] resp. states[blk]; both transitively implied by the
      round structure, kept for exact closure).

Footprint form (task_footprint) — block-granular ids over two disjoint
id spaces, states-block b -> b and new-states-block b -> M + b:
  A_i:  R = {blocks adjacent to i} (states),  W = {M + i}
  B_i:  R = {M + i},                          W = {i}
whose derived RAW / RAW+WAW+WAR rules are *identical* to the hand-written
predicates above (property-tested), and which puts SIRS scheduling on the
conflict-kernel path.

The recipe holds (subset id, type flag, step) — exactly the paper's "agent
subset identifier along with a binary flag indicating the task's type".
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import MABSModel
from repro.core.workersim import DESModel
from repro.topology import Topology, ring

S, I, R = 0, 1, 2


@dataclass
class SIRConfig:
    n_agents: int = 4_000
    k: int = 14                 # default ring degree (k/2 on each side)
    subset_size: int = 50       # s — chain granularity / task-size proxy
    p_si: float = 0.8
    p_ir: float = 0.1
    p_rs: float = 0.3
    i0: float = 0.05            # initial infected fraction

    @property
    def n_subsets(self) -> int:
        assert self.n_agents % self.subset_size == 0, (
            "subset_size must divide n_agents")
        return self.n_agents // self.subset_size

    @property
    def block_reach(self) -> int:
        """Ring aggregate-graph adjacency radius in blocks (incl. self=0);
        only meaningful for the default ring topology."""
        return -(-(self.k // 2) // self.subset_size)  # ceil division

    def tasks_per_step(self) -> int:
        return 2 * self.n_subsets


class SIRModel(MABSModel):
    name = "sir"

    def __init__(self, config: SIRConfig | None = None, *,
                 topology: Topology | None = None):
        """topology: contact network (None = ring of degree cfg.k, the
        paper's setup). Block adjacency is derived from the topology."""
        self.cfg = cfg = config or SIRConfig()
        self.topology = topology if topology is not None else ring(
            cfg.n_agents, cfg.k)
        assert self.topology.n_nodes == cfg.n_agents
        # Aggregate subset graph: [M]-node Topology with self loops (every
        # block adjacent to itself, block_graph guarantees it); its padded
        # neighbor rows double as the A-tasks' read-id footprints. Kept
        # in CSR form only — the dense [M, M] adjacency is guarded above
        # DENSE_LIMIT blocks, and adjacency tests are O(degree) row scans.
        self.block_topo = self.topology.block_graph(cfg.subset_size)

    # ------------------------------------------------------------- state
    def init_state(self, rng: jax.Array):
        cfg = self.cfg
        u = jax.random.uniform(rng, (cfg.n_agents,))
        states = jnp.where(u < cfg.i0, I, S).astype(jnp.int8)
        return {"states": states, "new_states": states}

    # ---------------------------------------------------------- creation
    def create_tasks(self, base_key: jax.Array, start_index, count: int):
        cfg = self.cfg
        m = cfg.n_subsets
        idx = start_index + jnp.arange(count)
        step = idx // (2 * m)
        within = idx % (2 * m)
        ttype = (within >= m).astype(jnp.int32)   # 0 = A (compute), 1 = B
        subset = (within % m).astype(jnp.int32)
        key = jax.vmap(lambda i: jax.random.fold_in(base_key, i))(idx)
        return {
            "subset": subset,
            "type": ttype,
            "step": step.astype(jnp.int32),
            "index": idx.astype(jnp.int32),
            "key": key,
        }

    # -------------------------------------------------------- dependence
    def _adjacent(self, b1, b2):
        """CSR membership test on the aggregate graph: b2 ∈ neighbors(b1)
        (broadcasts like the dense ``adj[b1, b2]`` lookup it replaces)."""
        nbrs = self.block_topo.neighbors[b1]            # [..., Db]
        return jnp.any((nbrs == b2[..., None]) & (nbrs >= 0), axis=-1)

    def task_footprint(self, recipes):
        """Block-granular id footprints (see module docstring):
        states-block b -> id b, new-states-block b -> id M + b."""
        m = self.cfg.n_subsets
        subset, ttype = recipes["subset"], recipes["type"]
        is_commit = (ttype == 1)[..., None]
        nbr_blocks = self.block_topo.neighbors[subset]    # [..., Db] states
        buf_row = jnp.full_like(nbr_blocks, -1).at[..., 0].set(m + subset)
        reads = jnp.where(is_commit, buf_row, nbr_blocks)
        writes = jnp.where(ttype == 1, subset, m + subset)[..., None]
        return reads.astype(jnp.int32), writes.astype(jnp.int32)

    def task_write_agents(self, recipes):
        """Agent rows written, for the sharded engine's ownership test.

        Unlike ``task_footprint`` (block ids over two abstract id spaces),
        these are actual state-row indices: task (subset, type) writes the
        contiguous rows [subset*s, (subset+1)*s) — of ``new_states`` for a
        compute, of ``states`` for a commit; both leaves shard identically
        so the buffer distinction doesn't matter for ownership."""
        s = self.cfg.subset_size
        offs = jnp.arange(s, dtype=jnp.int32)
        return recipes["subset"][..., None] * s + offs

    def task_read_agents(self, recipes):
        """Halo contract (actual state rows, buffer-agnostic — both
        leaves shard identically): a compute reads ``states`` over every
        adjacent block (its agents' contact neighborhoods live there, the
        self loop covers its own block); a commit reads ``new_states``
        over its own block only. Rows: block ids expanded by the subset
        size, [W, Db·s], -1 padded."""
        s = self.cfg.subset_size
        subset, ttype = recipes["subset"], recipes["type"]
        nbr_blocks = self.block_topo.neighbors[subset]        # [..., Db]
        own = jnp.full_like(nbr_blocks, -1).at[..., 0].set(subset)
        blocks = jnp.where((ttype == 1)[..., None], own, nbr_blocks)
        rows = blocks[..., None] * s + jnp.arange(s, dtype=jnp.int32)
        rows = jnp.where(blocks[..., None] >= 0, rows, -1)    # [..., Db, s]
        return rows.reshape(*subset.shape, -1).astype(jnp.int32)

    def conflicts(self, a, b, *, strict: bool = True):
        """later a vs earlier b — hand-written reference for the
        footprint-derived default (property-tested identical)."""
        same = a["subset"] == b["subset"]
        adj = self._adjacent(a["subset"], b["subset"])
        a_is_b = a["type"] == 1
        b_is_a = b["type"] == 0
        # paper rules
        commit_after_compute = a_is_b & b_is_a & same
        compute_after_commit = (~a_is_b) & (~b_is_a) & adj
        c = commit_after_compute | compute_after_commit
        if strict:
            # anti-dependence: a commit may not overtake a pending compute
            # of an adjacent subset (that compute still reads old states).
            c = c | (a_is_b & b_is_a & adj)
            # output hazards: two computes on the same subset (new_states)
            # and two commits on the same subset (states); both transitively
            # implied by the round structure, kept for exact closure.
            c = c | ((~a_is_b) & b_is_a & same)
            c = c | (a_is_b & (~b_is_a) & same)
        return c

    # --------------------------------------------------------- execution
    def _transition(self, states, agents, keys):
        """Synchronous SIRS transition for agent rows [..., s] given the
        per-row task keys; reads only ``states``."""
        cfg = self.cfg
        s_sz = agents.shape[-1]
        inf_frac = self.topology.neighbor_fraction(states == I, agents)
        cur = states[agents]
        u = jax.vmap(lambda k: jax.random.uniform(k, (s_sz,)))(keys)
        return jnp.where(
            (cur == S) & (u < cfg.p_si * inf_frac), I,
            jnp.where(
                (cur == I) & (u < cfg.p_ir), R,
                jnp.where((cur == R) & (u < cfg.p_rs), S, cur),
            ),
        ).astype(jnp.int8)

    def execute_wave(self, state, recipes, mask):
        cfg = self.cfg
        s_sz = cfg.subset_size
        states, new_states = state["states"], state["new_states"]

        subset = recipes["subset"]                      # [W]
        ttype = recipes["type"]                         # [W]
        agents = subset[:, None] * s_sz + jnp.arange(s_sz)[None, :]  # [W,s]

        # ---- type A: compute new states from current states ----
        nxt = self._transition(states, agents, recipes["key"])     # [W,s]

        do_a = mask & (ttype == 0)
        rows_a = jnp.where(do_a[:, None], agents, cfg.n_agents)    # OOB drop
        new_states = new_states.at[rows_a.reshape(-1)].set(
            nxt.reshape(-1), mode="drop")

        # ---- type B: commit new states ----
        do_b = mask & (ttype == 1)
        rows_b = jnp.where(do_b[:, None], agents, cfg.n_agents)
        committed = new_states[agents]
        states = states.at[rows_b.reshape(-1)].set(
            committed.reshape(-1), mode="drop")

        return {"states": states, "new_states": new_states}

    # ------------------------------------------------- DES model adapter
    def des_model(self, *, exec_cost=None, create_cost=None,
                  strict: bool = True) -> DESModel:
        cfg = self.cfg
        m = cfg.n_subsets
        block_nbrs = np.asarray(self.block_topo.neighbors)

        def recipes_fn(i: int):
            step, within = divmod(i, 2 * m)
            ttype, subset = (1, within - m) if within >= m else (0, within)
            return (subset, ttype)

        def record_new():
            return (set(), set())   # (computes_seen, commits_seen) subsets

        def record_add(rec, recipe):
            computes, commits = rec
            subset, ttype = recipe
            (commits if ttype else computes).add(subset)
            return rec

        def adjacent(b, seen: set) -> bool:
            row = block_nbrs[b]
            return any(int(b2) in seen for b2 in row[row >= 0])

        def depends(rec, recipe):
            computes, commits = rec
            subset, ttype = recipe
            if ttype == 1:  # commit
                d = subset in commits if strict else False
                if strict:
                    return d or adjacent(subset, computes)
                return subset in computes
            # compute
            d = adjacent(subset, commits)
            if strict:
                d = d or (subset in computes)
            return d

        c_exec = exec_cost if exec_cost is not None else (
            lambda r: (2e-8 * cfg.k if r[1] == 0 else 4e-9)
            * cfg.subset_size + 5e-7)
        c_create = create_cost if create_cost is not None else (lambda: 3e-7)
        return DESModel(
            recipes_fn=recipes_fn,
            exec_cost_fn=c_exec,
            create_cost_fn=c_create,
            record_new=record_new,
            record_add=record_add,
            depends=depends,
        )

    # -------------------------------------------------- reference stepper
    def reference_step(self, state, base_key: jax.Array, step: int):
        """Whole-system synchronous step (no protocol): the textbook SIRS
        update over all N agents at once. Uses the same per-subset task
        keys the protocol's A tasks of global step ``step`` would draw, so
        it is bit-exact vs running that step's 2M tasks through any engine
        (tested in tests/test_core_protocol.py)."""
        cfg = self.cfg
        m = cfg.n_subsets
        idx = step * 2 * m + jnp.arange(m)      # the step's A-task indices
        keys = jax.vmap(lambda i: jax.random.fold_in(base_key, i))(idx)
        agents = jnp.arange(cfg.n_agents, dtype=jnp.int32).reshape(
            m, cfg.subset_size)
        nxt = self._transition(state["states"], agents, keys).reshape(-1)
        return {"states": nxt, "new_states": nxt}
