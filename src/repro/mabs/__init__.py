from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
from repro.mabs.sir import SIRConfig, SIRModel
from repro.mabs.sis import SISConfig, SISModel
from repro.mabs.voter import VoterConfig, VoterModel

__all__ = [
    "AxelrodModel",
    "AxelrodConfig",
    "SIRModel",
    "SIRConfig",
    "SISModel",
    "SISConfig",
    "VoterModel",
    "VoterConfig",
]
