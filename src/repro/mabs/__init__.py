from repro.mabs.axelrod import AxelrodModel
from repro.mabs.sir import SIRModel

__all__ = ["AxelrodModel", "SIRModel"]
