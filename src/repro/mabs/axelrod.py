"""Axelrod-type cultural dynamics (paper §4.1, spec of Băbeanu et al. 2018).

N agents, each holding F traits with values in {0..q-1}, on a contact
network: the seed's complete-graph mixing by default, or any
``repro.topology.Topology`` (partner sampling is then network-restricted:
the target is a uniform neighbor of the source).
One *task* = one pairwise interaction (chain granularity, paper §3.4):

  creation  — draw source uniformly, target uniformly among the source's
              partners (all other agents, or its topology neighbors); bind
              the task's PRNG key (task depth: ids + randomness are fixed
              at creation; the trait work happens at execution).
  execution — overlap o = (1/F) Σ_f [s_f == t_f]; with probability o,
              if 0 < o < 1 and o >= 1 - ω (bounded confidence), the target
              copies one uniformly-chosen differing feature from the source.

Dependence rules (record, paper §3.5):

  paper rule  (strict=False): later task i depends on earlier j iff
      src_i == tgt_j  or  tgt_i == tgt_j          (flow + output hazards)
  strict rule (strict=True): adds the anti-dependence the paper's record
      omits:  tgt_i == src_j  (task i would overwrite what j still reads).
      Only the strict rule is bit-exact vs sequential execution; tests
      demonstrate the divergence of the paper rule (DESIGN.md §10).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.model import MABSModel
from repro.core.workersim import DESModel


@dataclass
class AxelrodConfig:
    n_agents: int = 10_000
    n_features: int = 3     # F — the paper's task-size proxy s
    q: int = 3              # traits per feature
    omega: float = 0.95     # bounded-confidence threshold


class AxelrodModel(MABSModel):
    name = "axelrod"

    def __init__(self, config: AxelrodConfig | None = None, *,
                 topology=None):
        """topology: optional repro.topology.Topology restricting partner
        sampling to network neighbors (None = complete-graph mixing, the
        seed behavior). Every node needs degree >= 1."""
        self.cfg = config or AxelrodConfig()
        self.topology = topology
        if topology is not None:
            assert topology.n_nodes == self.cfg.n_agents, (
                "topology size must match n_agents")
            assert int(topology.degrees.min()) >= 1, (
                "partner sampling needs every node to have a neighbor "
                "(isolated nodes would sample the -1 padding slot)")

    # ------------------------------------------------------------- state
    def init_state(self, rng: jax.Array):
        cfg = self.cfg
        traits = jax.random.randint(
            rng, (cfg.n_agents, cfg.n_features), 0, cfg.q, dtype=jnp.int32)
        return {"traits": traits}

    # ---------------------------------------------------------- creation
    def create_tasks(self, base_key: jax.Array, start_index, count: int):
        cfg = self.cfg
        idx = start_index + jnp.arange(count)

        topo = self.topology

        def one(i):
            k = jax.random.fold_in(base_key, i)
            ks, kt, kx = jax.random.split(k, 3)
            src = jax.random.randint(ks, (), 0, cfg.n_agents)
            if topo is None:
                # distinct target: draw from n-1 and shift past src
                tgt = jax.random.randint(kt, (), 0, cfg.n_agents - 1)
                tgt = jnp.where(tgt >= src, tgt + 1, tgt)
            else:
                # network-restricted: uniform neighbor of the source
                tgt = topo.sample_neighbor(kt, src)
            # kx is the execution key — randomness is *bound at creation*
            # (task-depth split), so scheduling cannot alter the trajectory.
            return src.astype(jnp.int32), tgt.astype(jnp.int32), kx

        src, tgt, key = jax.vmap(one)(idx)
        return {"src": src, "tgt": tgt, "index": idx.astype(jnp.int32),
                "key": key}

    # -------------------------------------------------------- dependence
    def task_footprint(self, recipes):
        """R = {src, tgt} (both trait rows are read), W = {tgt}. Property
        tests assert the derived rule is identical to the hand-written
        ``conflicts`` below for both strictness modes."""
        reads = jnp.stack([recipes["src"], recipes["tgt"]], axis=-1)
        writes = recipes["tgt"][..., None]
        return reads, writes

    def task_write_agents(self, recipes):
        """The interaction writes (at most) one feature of the target's
        trait row — the sharded engine's ownership key is tgt."""
        return recipes["tgt"][..., None]

    def task_read_agents(self, recipes):
        """Halo contract: both trait rows are read. tgt must be listed
        even though it is the write row — the interaction overwrites a
        single feature, so the rest of tgt's row carries through from its
        pre-wave value."""
        return jnp.stack([recipes["src"], recipes["tgt"]], axis=-1)

    def conflicts(self, a, b, *, strict: bool = True):
        """later a vs earlier b (broadcasting pytrees of id arrays).

        Hand-written reference for the footprint-derived default (kept as
        documentation of the paper's record rule and as the oracle for the
        footprint-identity property tests)."""
        c = (a["src"] == b["tgt"]) | (a["tgt"] == b["tgt"])  # paper record rule
        if strict:
            c = c | (a["tgt"] == b["src"])  # anti-dependence closure
        return c

    # --------------------------------------------------------- execution
    def execute_wave(self, state, recipes, mask):
        cfg = self.cfg
        traits = state["traits"]
        src, tgt, idx = recipes["src"], recipes["tgt"], recipes["index"]

        s_tr = traits[src]                      # [W, F]
        t_tr = traits[tgt]                      # [W, F]
        eq = s_tr == t_tr                       # [W, F]
        overlap = jnp.mean(eq.astype(jnp.float32), axis=-1)  # [W]

        # Execution randomness was bound at creation (recipe carries the key).
        def draw(k):
            ku, kf = jax.random.split(k)
            u = jax.random.uniform(ku)
            g = jax.random.uniform(kf, (cfg.n_features,))
            return u, g

        u, gumb = jax.vmap(draw)(recipes["key"])  # [W], [W, F]

        interact = (
            mask
            & (u < overlap)
            & (overlap < 1.0)
            & (overlap >= 1.0 - cfg.omega)
        )
        # choose one differing feature uniformly (random-keyed argmax trick)
        scores = jnp.where(~eq, gumb, -1.0)     # differing features only
        feat = jnp.argmax(scores, axis=-1)      # [W]
        new_val = jnp.take_along_axis(s_tr, feat[:, None], axis=-1)[:, 0]

        upd_rows = jnp.where(interact, tgt, cfg.n_agents)  # OOB drop when inactive
        updated = traits.at[upd_rows, feat].set(
            jnp.where(interact, new_val, 0), mode="drop")
        return {"traits": updated}

    # ------------------------------------------------- DES model adapter
    def des_model(self, *, seed: int = 0, exec_cost=None, create_cost=None,
                  strict: bool = True) -> DESModel:
        """Host-side adapter for the protocol simulator. Recipes are
        generated with NumPy identically-distributed to create_tasks."""
        cfg = self.cfg
        rs = np.random.RandomState(seed)
        topo_nbrs = topo_deg = None
        if self.topology is not None:
            topo_nbrs = np.asarray(self.topology.neighbors)
            topo_deg = np.asarray(self.topology.degrees)

        cache: dict[int, tuple[int, int]] = {}

        def recipes_fn(i: int):
            if i not in cache:
                src = int(rs.randint(cfg.n_agents))
                if topo_nbrs is None:
                    tgt = int(rs.randint(cfg.n_agents - 1))
                    if tgt >= src:
                        tgt += 1
                else:
                    tgt = int(topo_nbrs[src, rs.randint(topo_deg[src])])
                cache[i] = (src, tgt)
            return cache[i]

        # record: (targets_seen, sources_seen) as Python sets
        def record_new():
            return (set(), set())

        def record_add(rec, recipe):
            tgts, srcs = rec
            tgts.add(recipe[1])
            srcs.add(recipe[0])
            return rec

        def depends(rec, recipe):
            tgts, srcs = rec
            src, tgt = recipe
            d = (src in tgts) or (tgt in tgts)
            if strict:
                d = d or (tgt in srcs)
            return d

        c_exec = exec_cost if exec_cost is not None else (
            lambda r: 1e-7 * cfg.n_features + 5e-7)
        c_create = create_cost if create_cost is not None else (lambda: 3e-7)
        return DESModel(
            recipes_fn=recipes_fn,
            exec_cost_fn=c_exec,
            create_cost_fn=c_create,
            record_new=record_new,
            record_add=record_add,
            depends=depends,
        )
