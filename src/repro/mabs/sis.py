"""SIS (susceptible-infected-susceptible) epidemic on a contact network.

N agents with states S=0 / I=1 on an arbitrary topology. One *task* = one
asynchronous per-agent update (finest chain granularity — contrast SIRS'
block-synchronous mapping):

  creation  — draw agent v uniformly; bind the execution key.
  execution — S -> I with prob beta * (infected fraction of v's neighbors),
              I -> S with prob gamma; reads v's and its neighbors' states.

The dependence footprint is where the topology earns its keep: the task
reads {v} ∪ neighbors(v) — the padded neighbor row drops straight into the
read-id footprint, -1 slots and all — and writes {v}. ``conflicts`` is
inherited from the footprint default; scheduling parallelism now tracks
the graph structure (sparse graphs -> wide waves, hubs -> serialization),
which benchmarks/topology_sweep.py measures.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.model import MABSModel
from repro.topology import Topology

S, I = 0, 1


@dataclass
class SISConfig:
    beta: float = 0.6    # infection pressure per fully-infected neighborhood
    gamma: float = 0.15  # recovery probability
    i0: float = 0.1      # initial infected fraction


class SISModel(MABSModel):
    name = "sis"

    def __init__(self, topology: Topology, config: SISConfig | None = None):
        self.topology = topology
        self.cfg = config or SISConfig()

    # ------------------------------------------------------------- state
    def init_state(self, rng: jax.Array):
        u = jax.random.uniform(rng, (self.topology.n_nodes,))
        return {"states": jnp.where(u < self.cfg.i0, I, S).astype(jnp.int8)}

    # ---------------------------------------------------------- creation
    def create_tasks(self, base_key: jax.Array, start_index, count: int):
        topo = self.topology
        idx = start_index + jnp.arange(count)

        def one(i):
            k = jax.random.fold_in(base_key, i)
            kv, kx = jax.random.split(k)
            v = jax.random.randint(kv, (), 0, topo.n_nodes)
            return v.astype(jnp.int32), kx

        v, key = jax.vmap(one)(idx)
        return {"v": v, "index": idx.astype(jnp.int32), "key": key}

    # -------------------------------------------------------- dependence
    def task_footprint(self, recipes):
        """R = {v} ∪ neighbors(v) (padded row reused verbatim), W = {v}."""
        v = recipes["v"]
        reads = jnp.concatenate(
            [v[..., None], self.topology.neighbors[v]], axis=-1)
        return reads.astype(jnp.int32), v[..., None]

    def task_write_agents(self, recipes):
        """Writes land in row v — the sharded engine's ownership key."""
        return recipes["v"][..., None]

    def task_read_agents(self, recipes):
        """Halo contract: the footprint reads ARE state rows here —
        {v} ∪ neighbors(v), padded neighbor row included verbatim."""
        reads, _ = self.task_footprint(recipes)
        return reads

    # --------------------------------------------------------- execution
    def execute_wave(self, state, recipes, mask):
        cfg = self.cfg
        topo = self.topology
        states = state["states"]
        v = recipes["v"]

        inf_frac = topo.neighbor_fraction(states == I, v)        # [W]
        cur = states[v]
        u = jax.vmap(jax.random.uniform)(recipes["key"])         # [W]
        nxt = jnp.where(
            (cur == S) & (u < cfg.beta * inf_frac), I,
            jnp.where((cur == I) & (u < cfg.gamma), S, cur),
        ).astype(jnp.int8)

        rows = jnp.where(mask, v, topo.n_nodes)  # OOB drop when inactive
        states = states.at[rows].set(jnp.where(mask, nxt, 0), mode="drop")
        return {"states": states}
