"""Wall-clock timing helpers for calibration and benchmarks."""
from __future__ import annotations

import time
from typing import Callable

import jax


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0


def median_time(fn: Callable[[], object], repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time of fn() in seconds; blocks on JAX async dispatch."""
    for _ in range(warmup):
        out = fn()
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2]
