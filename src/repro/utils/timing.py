"""Wall-clock timing helpers for calibration and benchmarks."""
from __future__ import annotations

import time
from typing import Callable

import jax


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self.t0


def block_all(out):
    """Fence JAX async dispatch on *every* array leaf of ``out``.

    ``jax.block_until_ready`` already traverses pytrees, but the timing
    helpers fence leaf-by-leaf explicitly so a timed function returning a
    tuple/dict of arrays can never under-fence (a single un-awaited leaf
    would let queued device work leak out of the timed region and into
    the next repeat). Non-array leaves pass through untouched. Returns
    ``out``.
    """
    for leaf in jax.tree_util.tree_leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return out


class TimingResult(float):
    """The median seconds, behaving as a bare float everywhere — plus
    the full fenced per-repeat sample list (sorted ascending) for
    dispersion-aware consumers: the benchmark ledger and ``report.py
    compare`` widen their regression thresholds by the observed spread
    instead of trusting a bare median."""

    __slots__ = ("samples",)

    samples: tuple

    def __new__(cls, median: float, samples):
        self = super().__new__(cls, median)
        self.samples = tuple(float(s) for s in samples)
        return self

    @property
    def min_s(self) -> float:
        return self.samples[0]

    @property
    def rel_spread(self) -> float:
        """(max - min) / median over the repeats — 0.0 for a single
        repeat; the dispersion the compare thresholds widen by."""
        med = float(self)
        if not med or len(self.samples) < 2:
            return 0.0
        return (self.samples[-1] - self.samples[0]) / med


def median_time(fn: Callable[[], object], repeats: int = 5,
                warmup: int = 2) -> TimingResult:
    """Median wall time of ``fn()`` in seconds, fenced per repeat.
    Returns a ``TimingResult`` — a float subclass carrying the sorted
    per-repeat ``samples`` — so every existing float consumer is
    untouched while dispersion-aware callers get the full list.

    Warmup policy: ``warmup`` untimed calls run first and are fully
    fenced (``block_all`` on their outputs). The default of 2 covers the
    two cold effects a timed repeat must not pay: the first call traces
    and compiles; the second hits the compile cache and warms any
    dispatch-level caches (donated-buffer reuse, transfer plans). Fencing
    the warmup outputs also guarantees no queued device work crosses
    into the first timed repeat. Callers that warm up separately (e.g.
    the engine sweep, which needs the warmup run's stats) pass
    ``warmup=0`` — they own the fence then.

    Each timed repeat is fenced on every output leaf, so the measured
    span is real host+device wall time for the whole output pytree, not
    async-dispatch time of whichever leaf ``block_until_ready`` saw
    first fail to be an array.
    """
    for _ in range(warmup):
        block_all(fn())
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        block_all(fn())
        times.append(time.perf_counter() - t0)
    times.sort()
    return TimingResult(times[len(times) // 2], times)
