from repro.utils.pytree import tree_bytes, tree_param_count, tree_map_with_path_str
from repro.utils.timing import Timer, TimingResult, median_time

__all__ = [
    "tree_bytes",
    "tree_param_count",
    "tree_map_with_path_str",
    "Timer",
    "median_time",
    "TimingResult",
]
