"""Version compatibility shims for the jax API surface we use.

The repo targets the Pallas/TPU API as documented in the accelerator
guides; installed jax versions sometimes lag (or lead) those names.
Centralizing the fallbacks here keeps kernel and model code on the
canonical spelling.
"""
from __future__ import annotations

import jax

try:  # newer jax: top-level re-export, check_vma kwarg
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    import inspect

    from jax.experimental.shard_map import shard_map as _shard_map

    if "check_vma" in inspect.signature(_shard_map).parameters:
        shard_map = _shard_map
    else:

        def shard_map(*args, **kwargs):
            # old spelling of the replication check flag
            if "check_vma" in kwargs:
                kwargs["check_rep"] = kwargs.pop("check_vma")
            return _shard_map(*args, **kwargs)


def tpu_compiler_params(**kwargs):
    """pltpu.CompilerParams (new name) / pltpu.TPUCompilerParams (old)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:  # pragma: no cover - depends on jax version
        cls = pltpu.TPUCompilerParams
    return cls(**kwargs)
