"""PRNG stream discipline.

The paper's creation/execution depth split binds all randomness to a task at
*creation* time. We realize that by deriving a per-task key from a base key and
the task's global chain index — so the realized randomness is a pure function of
(seed, task index) and can never depend on execution order. This is what makes
wavefront execution bit-identical to sequential execution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def task_key(base_key: jax.Array, task_index: jax.Array) -> jax.Array:
    """Key for one task; task_index may be any integer array (vmappable)."""
    return jax.random.fold_in(base_key, task_index)


def task_keys(base_key: jax.Array, task_indices: jax.Array) -> jax.Array:
    """Vectorized task keys for a window of task indices [W] -> [W] keys."""
    return jax.vmap(lambda i: jax.random.fold_in(base_key, i))(task_indices)
