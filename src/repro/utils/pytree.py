"""Pytree helpers used across the framework (no flax — pure JAX pytrees)."""
from __future__ import annotations

from typing import Any, Callable

import jax
import numpy as np


def tree_param_count(tree: Any) -> int:
    """Total number of scalar elements in a pytree of arrays/ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(int(np.prod(l.shape)) for l in leaves))


def tree_bytes(tree: Any) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    leaves = jax.tree_util.tree_leaves(tree)
    total = 0
    for l in leaves:
        itemsize = np.dtype(l.dtype).itemsize
        total += int(np.prod(l.shape)) * itemsize
    return total


def tree_map_with_path_str(fn: Callable[[str, Any], Any], tree: Any) -> Any:
    """tree_map where fn receives a '/'-joined string path — used by the
    logical-axis sharding rules to match parameter names."""

    def _fn(path, leaf):
        parts = []
        for p in path:
            if isinstance(p, jax.tree_util.DictKey):
                parts.append(str(p.key))
            elif isinstance(p, jax.tree_util.SequenceKey):
                parts.append(str(p.idx))
            elif isinstance(p, jax.tree_util.GetAttrKey):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return fn("/".join(parts), leaf)

    return jax.tree_util.tree_map_with_path(_fn, tree)
