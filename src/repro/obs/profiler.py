"""Device-profile integration for the protocol phases.

Two complementary timing sources exist (docs/observability.md):

  * the host span tracer (obs/trace.py) — wall-clock structure per
    window/wave/boundary, fenced by ``block_until_ready``;
  * the XLA device profiler (``jax.profiler.trace``) — op-accurate
    device timelines, where the protocol phases show up by name because
    the scheduling kernels, halo gathers and window executors are
    wrapped in ``protocol.*`` named scopes (``annotate`` below).

``profile_session`` is the context helper the benchmarks wire in
(``benchmarks/engine_sweep.py --profile DIR``): a no-op when ``logdir``
is falsy, a ``jax.profiler.trace`` session otherwise — the resulting
TensorBoard/Perfetto profile groups device ops under the protocol
phase scopes.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax

#: named-scope alias used at every protocol phase boundary; a trace-time
#: label only — zero runtime cost, safe inside jit/shard_map/pallas
#: wrappers (the scope names the traced ops, it does not execute).
annotate = jax.named_scope


@contextmanager
def profile_session(logdir: str | None = None):
    """Device-profiler context: no-op when ``logdir`` is falsy, else a
    ``jax.profiler.trace`` session writing a TensorBoard-loadable
    profile (with the ``protocol.*`` scopes labeling the phases)."""
    if not logdir:
        yield None
        return
    with jax.profiler.trace(logdir):
        yield logdir
