"""Compiled-cost telemetry: what a scheduled window costs on the
compiler's terms.

The runtime layers (trace.py, stats.py) measure what the protocol *did*;
this module captures what the compiled executors *must* cost, straight
from XLA's ahead-of-time artifacts:

  * ``compiled.cost_analysis()``   — FLOPs and bytes accessed per call,
  * ``compiled.memory_analysis()`` — argument / output / temp buffer
    sizes (the peak-memory decomposition),
  * ``compiled.as_text()``         — post-optimization HLO, from which
    the per-device collective traffic is parsed.

The collective walker adapts ``launch/hlo_analysis.py``'s loop-trip
recovery to the engines' wave loops, with two twists that matter here:

  * The wave / chunk ``while_loop``s have **data-dependent** trip counts
    (``jnp.max(levels) + 1`` and the slab chunk ranges), so no
    ``constant(N)`` appears in the loop condition and static recovery
    returns nothing. Instead each collective is classified by its
    **dynamic-loop nesting depth** (1 = the wave loop, 2 = the split
    rung's chunk loop nested in it), and the *executed* iteration counts
    come from outside — the sharded engine's runtime comm ledger
    (``ShardedEngine.comm_iteration_counts``). Statically-counted loops
    (scan bodies with materialized trips) still multiply in as before.
  * Async collectives appear as ``-start``/``-done`` pairs; only the
    start op carries the transfer, so ``-done`` lines are skipped to
    avoid double counting.

The payoff is a *cross-check identity*: per-iteration collective receive
bytes × executed iterations must equal the runtime comm ledger's
``comm_bytes_total`` exactly on the sharded rungs (the ledger counts
per-device receive rows; SPMD-local HLO shapes are per-device receive
buffers). ``ledger_cross_check`` asserts it — a mismatch means either
the comm accounting or the compiled layout is wrong, which is precisely
the kind of silent bug this telemetry exists to catch.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable, Mapping, Sequence

from repro.launch.hlo_analysis import (
    _CALL_RE,
    _WHILE_RE,
    _WIRE_FACTOR,
    _shape_bytes,
    parse_computations,
    trip_count,
)

#: collective ops counted; ``-done`` halves of async pairs are skipped
_COLL_START_RE = re.compile(
    r"=\s+(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")

#: replica_groups on this toolchain print as {{0,1,...,7},{...}} (explicit
#: id lists), not the [n,m] iota form hlo_analysis expects — parse both
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int | None:
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUP_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return None


@dataclass(frozen=True)
class CollectiveOp:
    """One collective in the compiled module, with its loop context."""

    op: str              # "all-reduce" | "all-gather" | ...
    type_str: str        # result type (SPMD-local = per-device receive)
    bytes_per_call: int  # receive bytes per execution of the op
    static_mult: int     # product of statically-recovered trip counts
    depth: int           # dynamic (unknown-trip) while nesting depth
    group_size: int | None


@dataclass
class HloCollectives:
    """All collectives of one compiled executor, by loop context."""

    ops: list[CollectiveOp] = field(default_factory=list)

    def bytes_by_depth(self) -> dict[int, int]:
        """Per-call receive bytes summed per dynamic depth (static loop
        multipliers folded in) — multiply by executed iteration counts
        to get run totals."""
        out: dict[int, int] = {}
        for o in self.ops:
            out[o.depth] = out.get(o.depth, 0) + o.bytes_per_call * o.static_mult
        return out

    def total_bytes(self, iters_by_depth: Mapping[int, int]) -> int:
        """Total per-device receive bytes given the executed iteration
        count of each dynamic loop depth (depth 0 ops run once per
        executor call — pass ``{0: n_calls}`` to count them)."""
        return sum(b * int(iters_by_depth.get(d, 0))
                   for d, b in self.bytes_by_depth().items())

    def wire_bytes(self, iters_by_depth: Mapping[int, int]) -> float:
        """Ring-algorithm wire bytes (hlo_analysis cost model) under the
        same executed-iteration accounting."""
        total = 0.0
        for o in self.ops:
            n = o.group_size or 2
            total += (o.bytes_per_call * o.static_mult
                      * int(iters_by_depth.get(o.depth, 0))
                      * _WIRE_FACTOR[o.op](n))
        return total


def parse_collectives(hlo_text: str) -> HloCollectives:
    """Walk the compiled module from ENTRY, tracking static trip
    multipliers and dynamic while depth, and collect every collective."""
    blocks, entry = parse_computations(hlo_text)
    out = HloCollectives()

    def visit(name: str, static_mult: int, depth: int, seen: tuple):
        if name not in blocks or name in seen:
            return
        lines = blocks[name]
        body = "\n".join(lines)
        for line in lines:
            m = _COLL_START_RE.search(line)
            if not m:
                continue
            if m.group(3) == "-done":
                continue  # async completion — transfer counted at -start
            type_str, op = m.group(1), m.group(2)
            out.ops.append(CollectiveOp(
                op=op, type_str=type_str,
                bytes_per_call=_shape_bytes(type_str),
                static_mult=static_mult, depth=depth,
                group_size=_group_size(line)))
        for cond, wbody in _WHILE_RE.findall(body):
            cond_n, body_n = cond.lstrip("%"), wbody.lstrip("%")
            trips = trip_count(blocks.get(cond_n, []))
            if trips is None:
                # data-dependent trip count (the wave / chunk loops):
                # descend one dynamic depth; the executed count is
                # supplied at accounting time
                visit(body_n, static_mult, depth + 1, seen + (name,))
            else:
                visit(body_n, static_mult * trips, depth, seen + (name,))
        for callee in _CALL_RE.findall(body):
            visit(callee.lstrip("%"), static_mult, depth, seen + (name,))

    visit(entry, 1, 0, ())
    return out


@dataclass
class ExecutorCost:
    """Compiled-cost summary of one jitted engine executor."""

    name: str
    flops: float                 # cost_analysis, loop bodies counted once
    bytes_accessed: float        # same caveat
    argument_bytes: int
    output_bytes: int
    temp_bytes: int
    collectives: HloCollectives

    @property
    def peak_bytes(self) -> int:
        """Conservative peak live bytes: arguments + outputs + temps."""
        return self.argument_bytes + self.output_bytes + self.temp_bytes

    def as_row(self, iters_by_depth: Mapping[int, int] | None = None
               ) -> dict:
        """Flat JSON-safe dict for benchmark rows; with iteration counts
        the collective total is resolved, otherwise per-depth per-call
        bytes are recorded for later resolution."""
        row = {
            "executor": self.name,
            "flops": float(self.flops),
            "bytes_accessed": float(self.bytes_accessed),
            "argument_bytes": int(self.argument_bytes),
            "output_bytes": int(self.output_bytes),
            "temp_bytes": int(self.temp_bytes),
            "peak_bytes": int(self.peak_bytes),
            "collective_bytes_by_depth": {
                str(d): int(b)
                for d, b in self.collectives.bytes_by_depth().items()},
        }
        if iters_by_depth is not None:
            row["collective_bytes"] = int(
                self.collectives.total_bytes(iters_by_depth))
        return row


def executor_cost(fn: Callable, *args, name: str = "executor"
                  ) -> ExecutorCost:
    """AOT-lower + compile one jitted executor on example args and
    extract its compiled costs. Lowering never executes, so donated
    argument buffers are untouched."""
    compiled = fn.lower(*args).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # jax<=0.4.x returns [dict] on CPU
        ca = ca[0] if ca else {}
    ca = ca or {}
    try:
        mem = compiled.memory_analysis()
    except Exception:  # pragma: no cover - backend without memory stats
        mem = None
    return ExecutorCost(
        name=name,
        flops=float(ca.get("flops", 0.0)),
        bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        argument_bytes=int(getattr(mem, "argument_size_in_bytes", 0) or 0),
        output_bytes=int(getattr(mem, "output_size_in_bytes", 0) or 0),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0) or 0),
        collectives=parse_collectives(compiled.as_text()),
    )


@dataclass(frozen=True)
class CrossCheck:
    """HLO-parsed collective bytes vs the runtime comm ledger."""

    parsed_bytes: int
    ledger_bytes: int
    ratio: float
    ok: bool


def ledger_cross_check(costs: Mapping[str, ExecutorCost] | Sequence[ExecutorCost],
                       iters_by_depth: Mapping[int, int],
                       ledger_bytes: int, *, rtol: float = 0.0
                       ) -> CrossCheck:
    """Check the identity: per-iteration collective receive bytes ×
    executed iterations == the runtime comm ledger's byte total. Exact
    (``rtol=0``) on the sharded rungs — the ledger counts the same
    per-device receive rows the SPMD-local HLO shapes describe."""
    if isinstance(costs, Mapping):
        costs = list(costs.values())
    parsed = sum(c.collectives.total_bytes(iters_by_depth) for c in costs)
    ledger = int(ledger_bytes)
    ratio = parsed / ledger if ledger else (1.0 if not parsed else float("inf"))
    ok = abs(parsed - ledger) <= rtol * max(ledger, 1)
    return CrossCheck(parsed_bytes=int(parsed), ledger_bytes=ledger,
                      ratio=ratio, ok=ok)
