"""Observability subsystem: protocol tracing, metrics and reports.

The paper's central claim — adaptive, graceful handling of heterogeneous
computation — is only testable if the protocol can be *measured from the
inside*. This package is that layer:

  trace.py      — structured span tracer: per-window, per-wave and
                  per-boundary events (wave width, level, halo rows/bytes
                  per comm-ladder rung, overlap depth, schedule-vs-execute
                  split), exported as Chrome trace-event JSON (Perfetto-
                  loadable). Off by default; the engines' hot path adds
                  **zero** host syncs when no tracer is installed.
  stats.py      — typed, versioned stats registry: every engine stat is
                  declared once (type, group, docstring); engine ``run``
                  stats are validated against it and normalized to
                  host-native Python scalars at the registry boundary.
  profiler.py   — device-profile integration: ``jax.profiler.trace``
                  context helper plus the ``annotate`` named-scope alias
                  used to label protocol phases (levels/conflict kernels,
                  halo gathers, window executors) in device profiles.
  provenance.py — environment header (jax version, backend, device kind
                  and count, timestamp, git sha) stamped into the
                  benchmark artifacts.
  costs.py      — compiled-cost telemetry: AOT cost_analysis FLOPs /
                  bytes, memory decomposition and HLO-parsed per-device
                  collective traffic of the jitted window executors,
                  cross-checked against the runtime comm ledger.

See docs/observability.md for the span taxonomy and report walkthrough.
"""
from repro.obs.costs import (
    CrossCheck,
    ExecutorCost,
    HloCollectives,
    executor_cost,
    ledger_cross_check,
    parse_collectives,
)
from repro.obs.provenance import provenance
from repro.obs.stats import (
    STATS_VERSION,
    StatSpec,
    finalize_stats,
    registry,
    row_keys,
)
from repro.obs.trace import (
    SpanTracer,
    current_tracer,
    tracing,
    validate_chrome_trace,
)

__all__ = [
    "SpanTracer",
    "current_tracer",
    "tracing",
    "validate_chrome_trace",
    "StatSpec",
    "STATS_VERSION",
    "finalize_stats",
    "registry",
    "row_keys",
    "provenance",
    "ExecutorCost",
    "HloCollectives",
    "CrossCheck",
    "executor_cost",
    "parse_collectives",
    "ledger_cross_check",
]
