"""Typed, versioned engine-stats registry.

Every statistic an engine may emit from ``run`` is declared here exactly
once — key, type, group, nullability and a one-line meaning. The
registry replaces the former ``_extend_stats`` dict soup in three ways:

  * **Validation.** ``finalize_stats`` (called by every engine on its
    way out of ``run``) rejects undeclared keys, so a stat cannot be
    added without declaring its type and meaning here.
  * **Normalization.** Engine loops accumulate 0-d device arrays and
    numpy scalars; ``finalize_stats`` converts every value to a
    host-native Python scalar (int/float/bool/str, or a str->int dict)
    at the registry boundary, so BENCH JSON rows and test assertions
    never see device types.
  * **Schema derivation.** ``row_keys(group, ...)`` returns the declared
    keys of the given groups in declaration order —
    ``benchmarks/engine_sweep.py`` derives its nullable row columns from
    it instead of hand-listing them.

``STATS_VERSION`` is bumped whenever a key is added, removed or changes
meaning; the benchmark provenance header records it so old BENCH JSONs
stay interpretable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Mapping

#: bump on any change to the declared keys or their meaning
#: v2: serving group added; non-finite values rejected at the boundary
STATS_VERSION = 2

#: declaration groups, in rendering order
GROUPS = ("core", "device", "comm", "overlap", "serving")


@dataclass(frozen=True)
class StatSpec:
    key: str
    kind: str          # "int" | "float" | "bool" | "mapping"
    group: str         # one of GROUPS
    description: str
    nullable: bool = False

    def normalize(self, value: Any) -> Any:
        """Coerce one stat value to its declared host-native type."""
        if value is None:
            if self.nullable:
                return None
            raise ValueError(f"stat {self.key!r} is not nullable")
        if self.kind in ("int", "float"):
            # reject NaN/inf at the boundary: a silently-poisoned stat
            # (0/0 parallelism, overflowed counter) must never reach a
            # BENCH artifact or the benchmark ledger
            v = float(value)
            if not math.isfinite(v):
                raise ValueError(
                    f"stat {self.key!r} is non-finite ({v!r}) — refusing "
                    "to record it")
            return int(value) if self.kind == "int" else v
        if self.kind == "bool":
            return bool(value)
        if self.kind == "mapping":
            if not isinstance(value, Mapping):
                raise ValueError(
                    f"stat {self.key!r} expects a mapping, got "
                    f"{type(value).__name__}")
            return {str(k): int(v) for k, v in value.items()}
        raise ValueError(f"unknown stat kind {self.kind!r}")  # pragma: no cover


_REGISTRY: dict[str, StatSpec] = {}


def declare(key: str, kind: str, group: str, description: str, *,
            nullable: bool = False) -> StatSpec:
    assert group in GROUPS, group
    assert key not in _REGISTRY, f"stat {key!r} declared twice"
    spec = StatSpec(key, kind, group, description, nullable)
    _REGISTRY[key] = spec
    return spec


def registry() -> Mapping[str, StatSpec]:
    """The full declaration table (read-only view by convention)."""
    return _REGISTRY


def row_keys(*groups: str) -> tuple[str, ...]:
    """Declared keys of the given groups (all groups when empty), in
    declaration order — the derived row schema for the benchmark sweeps."""
    want = groups or GROUPS
    for g in want:
        assert g in GROUPS, g
    return tuple(s.key for s in _REGISTRY.values() if s.group in want)


def finalize_stats(stats: dict, *, strict: bool = True) -> dict:
    """Validate + normalize one engine ``run`` stats dict at the registry
    boundary: every key must be declared (unless ``strict=False``), and
    every value is converted to its declared host-native Python type —
    no 0-d device arrays or numpy scalars leak past this point."""
    out: dict = {}
    for key, value in stats.items():
        spec = _REGISTRY.get(key)
        if spec is None:
            if strict:
                raise ValueError(
                    f"undeclared engine stat {key!r} — declare it in "
                    f"repro/obs/stats.py (and bump STATS_VERSION)")
            out[key] = value
            continue
        out[key] = spec.normalize(value)
    return out


# --------------------------------------------------------------------------
# the declarations (docs/observability.md renders this table)

# core — every engine
declare("total_tasks", "int", "core", "tasks executed from the chain")
declare("n_windows", "int", "core", "windows the chain was cut into")
declare("total_waves", "int", "core",
        "executed (fused) waves over the whole run")
declare("mean_parallelism", "float", "core",
        "total_tasks / total_waves — mean tasks per wave")

# device — sharded engines
declare("n_devices", "int", "device", "mesh size over the agent axis")

# comm — sharded engines (all byte counts are per-device receive volume)
declare("halo", "bool", "comm", "some window used a halo layout "
        "(split, window or pair halo)", nullable=True)
declare("halo_split", "bool", "comm",
        "some window used the per-wave split rung", nullable=True)
declare("comm_modes", "mapping", "comm",
        "executed windows per comm-ladder rung, e.g. {'split': 5}",
        nullable=True)
declare("per_wave_gather_rows", "int", "comm",
        "mean rows shipped per executed wave", nullable=True)
declare("per_wave_comm_bytes", "int", "comm",
        "mean bytes shipped per executed wave", nullable=True)
declare("per_wave_split_rows", "float", "comm",
        "mean split-slab rows per wave (None when the split didn't run)",
        nullable=True)
declare("window_halo_rows", "int", "comm",
        "monolithic window/pair-halo reference rows per wave "
        "(padded N where that rung would replicate)", nullable=True)
declare("window_halo_bytes", "int", "comm",
        "the same reference in bytes", nullable=True)
declare("comm_reduction_vs_window_halo", "float", "comm",
        "window_halo_bytes / per_wave_comm_bytes — the split's win "
        "(1.0 on the monolithic rung)", nullable=True)
declare("full_state_bytes", "int", "comm",
        "replicated all_gather baseline bytes per wave", nullable=True)
declare("comm_bytes_total", "int", "comm",
        "rows actually shipped over the whole run, in bytes",
        nullable=True)

# overlap — windowed engines (the cross-window carry-over accounting)
declare("overlap", "bool", "overlap",
        "the overlapped (fused-boundary) loop actually ran",
        nullable=True)
declare("n_boundaries", "int", "overlap",
        "window transitions checked (n_windows - 1)", nullable=True)
declare("mean_overlap_depth", "float", "overlap",
        "mean tail waves of window k that also ran window k+1 tasks",
        nullable=True)
declare("max_overlap_depth", "int", "overlap",
        "max of the same over boundaries", nullable=True)
declare("overlap_tasks_early", "int", "overlap",
        "tasks executed before their window's barrier would have opened",
        nullable=True)
declare("carry_frontier_mean", "float", "overlap",
        "mean carry floor over next-window tasks (0 = independent head)",
        nullable=True)
declare("carry_frontier_max", "int", "overlap",
        "largest carry floor seen", nullable=True)

# serving — the continuous-batching engine (repro/serving/engine.py);
# its waves are protocol iterations, so the core keys apply unchanged
declare("serving_prefill_tasks", "int", "serving",
        "prefill-chunk tasks executed", nullable=True)
declare("serving_decode_tasks", "int", "serving",
        "decode-step tasks executed (batched per wave)", nullable=True)
declare("serving_requests_finished", "int", "serving",
        "requests completed (EOS or max_new_tokens)", nullable=True)
