"""Provenance headers for benchmark artifacts.

A BENCH JSON without its environment is unreproducible: CPU fallback vs
TPU, virtual vs real devices, and the code revision all change what the
numbers mean. ``provenance()`` captures the environment once and both
sweeps stamp it into their ``meta`` block; ``benchmarks/report.py mabs``
renders it above the tables.
"""
from __future__ import annotations

import datetime
import os
import platform
import subprocess

from repro.obs.stats import STATS_VERSION


def _git_sha() -> str | None:
    """Short sha of the repo this package lives in; None outside git."""
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    try:
        p = subprocess.run(
            ["git", "-C", root, "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10)
        return p.stdout.strip() or None if p.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def provenance() -> dict:
    """Environment header for a benchmark artifact: jax version, backend
    and device kind/count, UTC timestamp, git sha, stats schema version.
    Values are host-native JSON scalars."""
    import jax

    dev = jax.devices()[0]
    return {
        "jax_version": str(jax.__version__),
        "backend": str(jax.default_backend()),
        "device_kind": str(getattr(dev, "device_kind", "unknown")),
        "device_count": int(jax.device_count()),
        "timestamp": datetime.datetime.now(datetime.timezone.utc)
                     .isoformat(timespec="seconds"),
        "git_sha": _git_sha(),
        "stats_version": STATS_VERSION,
        # which box produced the numbers — the compare gate warns on
        # backend mismatch, but same-backend different-host comparisons
        # also deserve a visible provenance trail
        "hostname": platform.node() or None,
    }
