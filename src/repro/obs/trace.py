"""Structured span tracer for the wavefront protocol.

Records *where wall-clock time goes* per window, wave and boundary, and
exports Chrome trace-event JSON (the ``{"traceEvents": [...]}`` format
Perfetto / ``chrome://tracing`` load directly).

Design constraints (docs/observability.md):

* **Off by default, zero hot-path cost.** No tracer is installed unless
  the caller enters ``tracing()``; the engines guard every trace call
  with a single ``current_tracer() is None`` check, so the untraced hot
  path gains no host syncs, no allocations, no branches inside jit.
* **Fenced host timestamps.** With tracing on, span boundaries call
  ``jax.block_until_ready`` on the span's outputs, so a span's duration
  is real device+host wall time, not async-dispatch time. This
  deliberately serializes the double-buffered window pipeline — tracing
  trades throughput for attribution (the schedule-vs-execute split is
  exactly what the pipeline hides).
* **Honest per-wave timing.** Waves execute inside a fused
  ``lax.while_loop``; the host cannot observe individual iterations. Per
  -wave spans are therefore *attributed*: the measured window-execute
  span is subdivided proportionally to wave width, and each wave span
  carries ``"attributed": true`` plus its real schedule-derived
  attributes (level, width, halo rows/bytes per comm-ladder rung, per-
  device owned-task counts). Device-accurate per-phase timing comes from
  ``jax.profiler.trace`` + the ``protocol.*`` named scopes instead
  (obs/profiler.py).

Span taxonomy (all under pid 1, process "repro.protocol"):

  tid 0 "windows"  — B/E spans: ``run`` (whole engine run), ``schedule``
                     (one window's conflict+levels dispatch), ``execute``
                     (one window's wave drain), ``boundary`` (overlap
                     carry step: cross block + frontier + re-level).
  tid 1 "waves"    — X spans: one ``wave`` per executed (fused) wave,
                     width-proportional attribution inside its window.
  tid 2 "comm"     — X spans: one ``halo_gather`` per wave that shipped
                     rows, with ``rung``/``rows``/``bytes`` attributes.

Usage:

    from repro.obs import tracing

    with tracing() as tr:
        state, stats = engine.run(state, total)
    tr.export("trace.json")           # -> load in ui.perfetto.dev
"""
from __future__ import annotations

import json
import time
from contextlib import contextmanager
from typing import Any

#: Chrome trace-event phases the tracer emits / the validator accepts.
PHASES = frozenset({"B", "E", "X", "i", "I", "C", "M"})

PID = 1
TID_WINDOWS = 0
TID_WAVES = 1
TID_COMM = 2

_THREAD_NAMES = {TID_WINDOWS: "windows", TID_WAVES: "waves",
                 TID_COMM: "comm"}


class Span:
    """An open (or closed) B/E span; ``args`` may be extended until
    export — the engines attach outputs that only exist after the fence
    (e.g. the executed wave count) to an already-entered span."""

    __slots__ = ("name", "cat", "tid", "args", "t0", "t1")

    def __init__(self, name: str, cat: str, tid: int, args: dict):
        self.name = name
        self.cat = cat
        self.tid = tid
        self.args = args
        self.t0: float = 0.0
        self.t1: float | None = None


class SpanTracer:
    """Collects trace events in memory; export renders Chrome JSON.

    Not thread-safe by design: the engines' run loops are single-
    threaded hosts, and the tracer is installed per ``tracing()`` block.
    """

    def __init__(self, *, process_name: str = "repro.protocol"):
        self.process_name = process_name
        self._spans: list[Span] = []          # closed + open B/E spans
        self._events: list[dict] = []         # X / i / C events
        self._stack: list[Span] = []          # open spans (tid 0 only)
        self._epoch = time.perf_counter()

    # ------------------------------------------------------------ clock
    def _now_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    # ------------------------------------------------------------ spans
    @contextmanager
    def span(self, name: str, *, cat: str = "protocol",
             tid: int = TID_WINDOWS, **args: Any):
        """B/E span around a block. The yielded ``Span`` exposes ``args``
        (mutable until export) and, after exit, ``t0``/``t1`` in µs —
        ``subdivide`` uses them to attribute child wave spans. The caller
        is responsible for fencing device work inside the block (the
        engines call ``jax.block_until_ready`` before exiting) so the
        recorded duration is real wall time."""
        sp = Span(name, cat, tid, dict(args))
        sp.t0 = self._now_us()
        self._stack.append(sp)
        self._spans.append(sp)
        try:
            yield sp
        finally:
            sp.t1 = self._now_us()
            self._stack.pop()

    def instant(self, name: str, *, cat: str = "protocol",
                tid: int = TID_WINDOWS, **args: Any) -> None:
        self._events.append({"name": name, "ph": "i", "cat": cat,
                             "ts": self._now_us(), "pid": PID, "tid": tid,
                             "s": "t", "args": args})

    def complete(self, name: str, ts_us: float, dur_us: float, *,
                 cat: str = "protocol", tid: int = TID_WAVES,
                 **args: Any) -> None:
        """X (complete) event with explicit timestamps."""
        self._events.append({"name": name, "ph": "X", "cat": cat,
                             "ts": float(ts_us), "dur": float(dur_us),
                             "pid": PID, "tid": tid, "args": args})

    def subdivide(self, parent: Span, name: str, weights, args_list, *,
                  tid: int = TID_WAVES, cat: str = "protocol",
                  ) -> list[tuple[float, float]]:
        """Attribute ``parent``'s measured duration to child X spans in
        proportion to ``weights`` (the engines pass wave widths — see the
        module docstring for why per-wave timing is attribution, not
        measurement). ``args_list[i]`` extends child i's args. Returns
        the children's (ts, dur) slots so the caller can align further
        events (e.g. per-wave halo-gather spans) with them."""
        assert parent.t1 is not None, "subdivide() needs a closed span"
        total = float(sum(weights)) or 1.0
        dur = parent.t1 - parent.t0
        t = parent.t0
        slots: list[tuple[float, float]] = []
        for i, (wgt, extra) in enumerate(zip(weights, args_list)):
            d = dur * float(wgt) / total
            self.complete(name, t, d, tid=tid, cat=cat,
                          index=i, attributed=True, **extra)
            slots.append((t, d))
            t += d
        return slots

    # ----------------------------------------------------------- export
    def events(self) -> list[dict]:
        """Render every recorded event as a Chrome trace-event dict."""
        out: list[dict] = [
            {"name": "process_name", "ph": "M", "pid": PID, "tid": 0,
             "args": {"name": self.process_name}},
        ]
        for tid, tname in _THREAD_NAMES.items():
            out.append({"name": "thread_name", "ph": "M", "pid": PID,
                        "tid": tid, "args": {"name": tname}})
        for sp in self._spans:
            out.append({"name": sp.name, "ph": "B", "cat": sp.cat,
                        "ts": sp.t0, "pid": PID, "tid": sp.tid,
                        "args": dict(sp.args)})
            out.append({"name": sp.name, "ph": "E", "cat": sp.cat,
                        "ts": sp.t1 if sp.t1 is not None else self._now_us(),
                        "pid": PID, "tid": sp.tid})
        out.extend(self._events)
        # stable ts order (ties keep emission order, so an E at the same
        # timestamp as the next B stays correctly nested)
        out.sort(key=lambda e: e.get("ts", 0.0))
        return out

    def to_chrome_trace(self) -> dict:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms",
                "otherData": {"tracer": "repro.obs", "version": 1}}

    def export(self, path: str | None = None) -> dict:
        """Chrome trace-event payload; also written to ``path`` if given."""
        payload = self.to_chrome_trace()
        if path is not None:
            with open(path, "w") as f:
                json.dump(payload, f)
        return payload

    def __len__(self) -> int:
        return 2 * len(self._spans) + len(self._events)


# --------------------------------------------------------------------------
# the installed tracer (module global; None = tracing off, the default)

_CURRENT: SpanTracer | None = None


def current_tracer() -> SpanTracer | None:
    """The installed tracer, or None (the default: tracing off). Engines
    check this exactly once per run and skip every trace branch when it
    is None — the untraced hot path stays sync-free."""
    return _CURRENT


@contextmanager
def tracing(tracer: SpanTracer | None = None):
    """Install a tracer for the duration of the block (and restore the
    previous one after — blocks nest)."""
    global _CURRENT
    prev = _CURRENT
    tr = tracer if tracer is not None else SpanTracer()
    _CURRENT = tr
    try:
        yield tr
    finally:
        _CURRENT = prev


# --------------------------------------------------------------------------
# schema validation (tests + the CI trace-export smoke)

def validate_chrome_trace(payload: Any) -> int:
    """Validate a Chrome trace-event payload; returns the event count.

    Checks the invariants the tests and the CI smoke step pin:
      * top level is ``{"traceEvents": [...]}`` (or a bare event list);
      * every event carries name/ph/pid/tid, a known phase, and a
        non-negative ``ts`` (metadata ``M`` events are exempt from ts);
      * ``X`` events carry a non-negative ``dur``;
      * per (pid, tid), in timestamp order, ``B``/``E`` events form a
        properly nested stack with matching names and non-decreasing
        timestamps (every span closed, no cross-nesting).

    Raises ``ValueError`` on the first violation.
    """
    if isinstance(payload, dict):
        events = payload.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("payload has no traceEvents list")
    elif isinstance(payload, list):
        events = payload
    else:
        raise ValueError(f"not a trace payload: {type(payload).__name__}")

    lanes: dict[tuple, list[dict]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event {i} ({ev.get('name')!r}) "
                                 f"missing {k!r}")
        ph = ev["ph"]
        if ph not in PHASES:
            raise ValueError(f"event {i} ({ev['name']!r}) has unknown "
                             f"phase {ph!r}")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} ({ev['name']!r}) has bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"X event {i} ({ev['name']!r}) has bad "
                                 f"dur {dur!r}")
        lanes.setdefault((ev["pid"], ev["tid"]), []).append(ev)

    for (pid, tid), lane in lanes.items():
        lane = sorted(lane, key=lambda e: e["ts"])  # stable: ties keep order
        stack: list[dict] = []
        last_ts = 0.0
        for ev in lane:
            if ev["ts"] < last_ts:
                raise ValueError(
                    f"tid {tid}: timestamps regress at {ev['name']!r}")
            last_ts = ev["ts"]
            if ev["ph"] == "B":
                stack.append(ev)
            elif ev["ph"] == "E":
                if not stack:
                    raise ValueError(
                        f"tid {tid}: E {ev['name']!r} without open B")
                top = stack.pop()
                if top["name"] != ev["name"]:
                    raise ValueError(
                        f"tid {tid}: E {ev['name']!r} closes B "
                        f"{top['name']!r} (cross-nested spans)")
        if stack:
            raise ValueError(
                f"tid {tid}: {len(stack)} unclosed span(s), first open: "
                f"{stack[0]['name']!r}")
    return len(events)
