"""Multi-device wavefront engine: waves sharded over the agent axis.

One engine body serves a *ladder* of communication layouts, decided per
run from the model's row contracts and the schedule shape (most to least
specialized — each rung degrades to the next when it cannot win):

**Per-wave halo split** (``sharded``, the default top rung) — the paper's
protocol only pays off when per-wave work *and communication* stay
proportional to the localized update footprint. Wave levels are known at
schedule time, so the window's halo (the read ∪ write state rows of its
tasks) is split into per-wave slabs: wave w gathers only the rows of
tasks at level w. Slab widths are heavily skewed (level 0 usually holds
most of a window, tail waves a handful), so instead of a rectangular
[n_waves, max_slab] padding the slabs are laid out wave-major in
fixed-width *chunks* (``distributed.sharding.wave_halo_split``): wave w
owns a dynamic number of static-width chunk gathers, shipping
ceil(rows_w / chunk)·chunk ≈ rows_w rows. Summed over a window that is
≈ *one* window halo instead of n_waves of them — per-wave comm drops by
~n_waves vs the monolithic layout below. All shapes are static: the
layout builds inside the jitted executors on replicated values, no host
sync, no per-window recompilation.

**Window halo** (``sharded_window_halo``, the monolithic middle rung) —
the PR-3 layout: every state leaf leads with the agent axis and is
sharded into contiguous row blocks over a 1-D ``("agents",)`` mesh. At
schedule time (replicated, so no extra comm) the engine derives the
window's halo from the model's ``task_read_agents`` /
``task_write_agents`` contracts — degree-bounded, padded to the static
width W·(nr+nw) — and every wave, inside ``shard_map``:

  1. gathers the halo rows: each row has a unique owner shard; owners
     contribute, one ``psum`` over the agent axis delivers the rows
     everywhere — O(halo) values per device instead of the all_gather's
     O(N);
  2. scatters them into a full-size scratch buffer and refreshes the
     local row block from the authoritative local shard (a local copy,
     no comm) — every row an owned task can read is now current; rows
     outside halo ∪ local block stay stale zeros and are provably never
     read;
  3. restricts the wave mask to *owned* tasks (a task executes on every
     device whose row block contains one of its write targets) and runs
     the model's vectorized ``execute_wave`` on the scratch;
  4. keeps only the local row block of the result — writes land directly
     on their owners, so no write scatter is communicated at all.

The split executor replaces step 1-2 with the per-wave chunk loop; steps
3-4 are identical, so bit-exactness is untouched.

**Replicated all_gather** (``sharded_replicated``, the bottom rung) —
the historic layout: per wave, ``all_gather`` the state shards into the
full agent state and execute on that. Models that do not declare the
read/write row contracts route here automatically, as does any
monolithic run whose halo would not beat the full state (halo width
>= N; the split rung only needs a chunk narrower than the state).

**Cross-window overlap** (``overlap=True`` / ``sharded_overlap``): the
window boundary stops draining at a barrier — window k+1's head waves
execute fused with window k's tail (see ``WindowedEngine``). Per fused
wave the gather must deliver every row *either* window can touch. The
split rung handles this natively: the pair's rows and levels concatenate
and re-split into fused-wave slabs (rebuilt every boundary, because the
carry re-leveling moves tasks between waves), so fused waves still ship
only what they read. The monolithic rung falls back to the *pair halo* —
the union of both windows' read ∪ write rows
(``distributed.sharding.pair_halo``, static width 2·W·(nr+nw)) — and its
halo-vs-full-state decision uses that doubled width. Each fused wave
executes window k's owned tasks at that level, then window k+1's on the
same scratch — legal because the carry frontier guarantees a fused wave
never holds conflicting tasks.

Window-local objects (recipes, validity, conflict matrix, wave levels,
slab layouts) are O(W)/O(W²) and stay replicated in every mode;
scheduling runs once and its outputs broadcast to the mesh. All modes
are bit-exact vs the sequential oracle under the strict rule
(property-tested under 8 virtual devices), and report their comm volume
in ``run`` stats (``per_wave_comm_bytes`` actually shipped vs
``window_halo_bytes`` monolithic vs ``full_state_bytes``).

The ``WindowedEngine`` loop double-buffers windows: window t+1's schedule
is dispatched before the engine blocks on window t's waves.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    AGENT_AXIS as AXIS,
    agent_state_shardings,
    agents_mesh,
    halo_gather,
    halo_scatter,
    pair_halo,
    wave_halo_gather,
    wave_halo_split,
    window_halo,
)
from repro.engine.base import WindowedEngine, register_engine
from repro.obs.profiler import annotate
from repro.utils.compat import shard_map


@register_engine
class ShardedEngine(WindowedEngine):
    name = "sharded"

    #: None = probe the model for the halo contracts; False = always
    #: replicate (the ``sharded_replicated`` registry entry).
    halo: bool | None = None

    #: per-wave halo splitting — the top rung of the comm ladder. None =
    #: on whenever the halo contracts are available; False pins the
    #: monolithic window/pair halo (the ``sharded_window_halo`` entry).
    split: bool | None = None

    def __init__(self, model, *, window: int = 256, strict: bool = True,
                 devices=None, jit: bool = True, halo: bool | None = None,
                 split: bool | None = None, chunk: int = 16,
                 overlap: bool | None = None):
        super().__init__(model, window=window, strict=strict,
                         overlap=overlap)
        self.mesh = agents_mesh(devices)
        self.n_devices = self.mesh.devices.size
        self._jit = jit
        self._built_for: int | None = None  # n_agents the fns were built for
        self._win_comm: list = []           # per-window comm ledger
        if halo is not None:
            self.halo = halo
        if split is not None:
            self.split = split
        #: slab chunk width (rows per collective) for the split rung —
        #: trades collective count (latency) against padding (bandwidth)
        self.chunk = int(chunk)
        assert self.chunk >= 1, "chunk must be a positive row count"
        self._halo_slots = 0
        if self.halo is None or self.halo:
            # one-shot host probe: the halo layout needs both row contracts
            probe = model.create_tasks(jax.random.key(0), 0, 1)
            reads = model.task_read_agents(probe)
            writes = model.task_write_agents(probe)
            if self.halo is None:
                self.halo = reads is not None and writes is not None
            elif reads is None or writes is None:
                raise ValueError(
                    f"halo=True needs {type(model).__name__} to implement "
                    "both task_read_agents and task_write_agents; use the "
                    "'sharded_replicated' engine (or halo=None auto-probe) "
                    "for models without the row contracts")
            if self.halo:
                self._halo_slots = reads.shape[-1] + writes.shape[-1]

        def _halo_parts(recipes):
            """(writes, monolithic halo, per-task rows) — the last two
            None without the row contracts."""
            writes = model.task_write_agents(recipes)
            if not self.halo:
                return writes, None, None
            reads = model.task_read_agents(recipes)
            return (writes, window_halo(reads, writes),
                    jnp.concatenate([reads, writes], axis=1))

        def _schedule(base_key, start, count):
            recipes, _, levels = self._schedule_window(base_key, start, count)
            return (recipes, levels) + _halo_parts(recipes)

        self._schedule = jax.jit(_schedule) if jit else _schedule

        def _schedule_ov(base_key, start, count):
            recipes, valid, conf = self._schedule_window_ov(
                base_key, start, count)
            return recipes, valid, conf, _halo_parts(recipes)

        self._schedule_ov = jax.jit(_schedule_ov) if jit else _schedule_ov

    # ------------------------------------------------------------ build
    def _build(self, n_agents: int):
        """Compile the sharded window executors for one agent count."""
        if self._built_for == n_agents:
            return
        model, d = self.model, self.n_devices
        n_pad = -(-n_agents // d) * d
        shard_n = n_pad // d
        halo_width = self.window * self._halo_slots
        # monolithic fallback-rung decisions: a degenerate halo (>= full
        # state) means replication ships fewer bytes. The barrier/drain
        # executor decides on the single-window width; monolithic fused
        # waves gather the union of both windows' halos, so the pair
        # executor decides on the doubled width independently (a window
        # size whose single halo wins can lose once doubled). The split
        # rung needs no such guard: it ships ~one halo per *window*, so
        # it only degrades when a single chunk cannot beat the state.
        use_halo = self.halo and halo_width < n_agents
        use_halo_pair = self.halo and 2 * halo_width < n_agents
        use_split = (self.halo and self.split is not False
                     and self.chunk < n_agents)

        def _pad(x):
            return jnp.pad(x, [(0, n_pad - n_agents)]
                           + [(0, 0)] * (x.ndim - 1))

        def read_view(loc, halo, local_rows, use):
            """Every row the wave's owned tasks may read, fresh —
            monolithic variant (whole halo, or the full state)."""
            if not use:
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.all_gather(
                        x, AXIS, axis=0, tiled=True)[:n_agents], loc)

            def one(x):
                g = halo_gather(x, halo, shard_n=shard_n)
                scratch = jnp.zeros((n_agents,) + x.shape[1:], x.dtype)
                scratch = halo_scatter(scratch, halo, g)
                # local block is authoritative — refresh it so the
                # end-of-wave slice keeps unwritten rows exact
                return scratch.at[local_rows].set(x, mode="drop")
            return jax.tree_util.tree_map(one, loc)

        def slab_view(loc, slabs, chunk_start, w, local_rows):
            """Per-wave variant: refresh only wave w's slab chunks —
            a dynamic number of static-width gathers; an empty wave
            (zero chunks) issues no collective at all."""
            c1 = chunk_start[w + 1]

            def chunk_body(carry):
                c, scr = carry

                def one(x, s):
                    g, slab = wave_halo_gather(x, slabs, c, shard_n=shard_n)
                    return halo_scatter(s, slab, g)
                return c + 1, jax.tree_util.tree_map(one, loc, scr)

            scratch = jax.tree_util.tree_map(
                lambda x: jnp.zeros((n_agents,) + x.shape[1:], x.dtype), loc)
            _, scratch = jax.lax.while_loop(
                lambda c: c[0] < c1, chunk_body,
                (chunk_start[w], scratch))
            return jax.tree_util.tree_map(
                lambda x, s: s.at[local_rows].set(x, mode="drop"),
                loc, scratch)

        def owned_mask(levels, write_agents, w, lo):
            mask = levels == w
            if write_agents is not None:
                owned = jnp.any(
                    (write_agents >= lo) & (write_agents < lo + shard_n),
                    axis=-1)
                mask = mask & owned
            return mask

        def keep_local(new, lo):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    _pad(x), lo, shard_n, axis=0), new)

        def window_local(local_state, recipes, levels, write_agents, halo):
            # runs per-device inside shard_map; local leaves are [N/d, ...]
            lo = jax.lax.axis_index(AXIS) * shard_n
            local_rows = lo + jnp.arange(shard_n)
            n_waves = jnp.max(levels) + 1

            def body(carry):
                w, loc = carry
                full = read_view(loc, halo, local_rows, use_halo)
                new = model.execute_wave(
                    full, recipes, owned_mask(levels, write_agents, w, lo))
                return w + 1, keep_local(new, lo)

            _, local_state = jax.lax.while_loop(
                lambda c: c[0] < n_waves, body,
                (jnp.int32(0), local_state))
            return local_state, n_waves

        def window_split_local(local_state, recipes, levels, write_agents,
                               slabs, chunk_start):
            lo = jax.lax.axis_index(AXIS) * shard_n
            local_rows = lo + jnp.arange(shard_n)
            n_waves = jnp.max(levels) + 1

            def body(carry):
                w, loc = carry
                full = slab_view(loc, slabs, chunk_start, w, local_rows)
                new = model.execute_wave(
                    full, recipes, owned_mask(levels, write_agents, w, lo))
                return w + 1, keep_local(new, lo)

            _, local_state = jax.lax.while_loop(
                lambda c: c[0] < n_waves, body,
                (jnp.int32(0), local_state))
            return local_state, n_waves

        def window_pair_local(local_state, rec_a, lv_a, wa_a,
                              rec_b, lv_b, wa_b, halo):
            # fused drain of window k (a) overlapped with window k+1 (b);
            # halo is the pair union, so one gather serves both windows
            lo = jax.lax.axis_index(AXIS) * shard_n
            local_rows = lo + jnp.arange(shard_n)
            n_waves = jnp.max(lv_a) + 1

            def body(carry):
                w, loc = carry
                full = read_view(loc, halo, local_rows, use_halo_pair)
                new = model.execute_wave(
                    full, rec_a, owned_mask(lv_a, wa_a, w, lo))
                # b's reads never overlap a's same-wave writes (the carry
                # frontier forbids conflicts inside a fused wave), so
                # executing b on a's output scratch is exact
                new = model.execute_wave(
                    new, rec_b, owned_mask(lv_b, wa_b, w, lo))
                return w + 1, keep_local(new, lo)

            _, local_state = jax.lax.while_loop(
                lambda c: c[0] < n_waves, body,
                (jnp.int32(0), local_state))
            return local_state, n_waves

        def window_pair_split_local(local_state, rec_a, lv_a, wa_a,
                                    rec_b, lv_b, wa_b, slabs, chunk_start):
            # fused drain on the split rung: slabs hold the per-fused-wave
            # union of both windows' rows, so one chunk loop serves both
            lo = jax.lax.axis_index(AXIS) * shard_n
            local_rows = lo + jnp.arange(shard_n)
            n_waves = jnp.max(lv_a) + 1

            def body(carry):
                w, loc = carry
                full = slab_view(loc, slabs, chunk_start, w, local_rows)
                new = model.execute_wave(
                    full, rec_a, owned_mask(lv_a, wa_a, w, lo))
                new = model.execute_wave(
                    new, rec_b, owned_mask(lv_b, wa_b, w, lo))
                return w + 1, keep_local(new, lo)

            _, local_state = jax.lax.while_loop(
                lambda c: c[0] < n_waves, body,
                (jnp.int32(0), local_state))
            return local_state, n_waves

        window_sharded = shard_map(
            window_local, mesh=self.mesh,
            in_specs=(P(AXIS), P(), P(), P(), P()),
            out_specs=(P(AXIS), P()),
            check_vma=False)

        window_split_sharded = shard_map(
            window_split_local, mesh=self.mesh,
            in_specs=(P(AXIS), P(), P(), P(), P(), P()),
            out_specs=(P(AXIS), P()),
            check_vma=False)

        window_pair_sharded = shard_map(
            window_pair_local, mesh=self.mesh,
            in_specs=(P(AXIS), P(), P(), P(), P(), P(), P(), P()),
            out_specs=(P(AXIS), P()),
            check_vma=False)

        window_pair_split_sharded = shard_map(
            window_pair_split_local, mesh=self.mesh,
            in_specs=(P(AXIS), P(), P(), P(), P(), P(), P(), P(), P()),
            out_specs=(P(AXIS), P()),
            check_vma=False)

        chunk, n_waves_max = self.chunk, self.window

        def _exec_mono(state, recipes, levels, write_agents, halo):
            with annotate("protocol.execute_window"):
                return window_sharded(state, recipes, levels, write_agents,
                                      halo)

        def _exec_split(state, recipes, levels, write_agents, rows):
            slabs, chunk_start = wave_halo_split(
                rows, levels, n_waves_max=n_waves_max, chunk=chunk)
            with annotate("protocol.execute_window"):
                state, n_waves = window_split_sharded(
                    state, recipes, levels, write_agents, slabs, chunk_start)
            # rows actually gathered this window (every executed wave's
            # chunk range) — the comm ledger entry for the stats
            shipped = chunk_start[n_waves] * chunk
            return state, n_waves, shipped

        def _exec_pair_mono(state, rec_a, lv_a, wa_a, rec_b, lv_b, wa_b,
                            halo):
            with annotate("protocol.execute_pair"):
                state, n_waves = window_pair_sharded(
                    state, rec_a, lv_a, wa_a, rec_b, lv_b, wa_b, halo)
            # rebase the next window onto the new level clock; executed
            # (and invalid) tasks drop to -1
            lv_b = jnp.where(lv_b >= n_waves, lv_b - n_waves, -1)
            return state, n_waves, lv_b

        def _exec_pair_split(state, rec_a, lv_a, wa_a, rec_b, lv_b, wa_b,
                             rows_a, rows_b):
            # re-split at every boundary: the carry re-leveling moves
            # window b's tasks between fused waves, and rebasing retires
            # window a's drained tasks (level -1 rows drop from the slabs)
            rows = jnp.concatenate([rows_a, rows_b], axis=0)
            lvs = jnp.concatenate([lv_a, lv_b])
            slabs, chunk_start = wave_halo_split(
                rows, lvs, n_waves_max=n_waves_max, chunk=chunk)
            with annotate("protocol.execute_pair"):
                state, n_waves = window_pair_split_sharded(
                    state, rec_a, lv_a, wa_a, rec_b, lv_b, wa_b,
                    slabs, chunk_start)
            lv_b = jnp.where(lv_b >= n_waves, lv_b - n_waves, -1)
            shipped = chunk_start[n_waves] * chunk
            return state, n_waves, lv_b, shipped

        if self._jit:
            _exec_mono = jax.jit(_exec_mono, donate_argnums=(0,))
            _exec_split = jax.jit(_exec_split, donate_argnums=(0,))
            _exec_pair_mono = jax.jit(_exec_pair_mono, donate_argnums=(0,))
            _exec_pair_split = jax.jit(_exec_pair_split, donate_argnums=(0,))

        dummy_halo = jnp.full((1,), -1, jnp.int32)
        # jit-boundary hooks for the compiled-cost telemetry
        # (repro.obs.costs): which executor a barrier run dispatches to,
        # and the rung decisions that pick it
        self._jit_execs = {"mono": _exec_mono, "split": _exec_split,
                           "pair_mono": _exec_pair_mono,
                           "pair_split": _exec_pair_split}
        self._use_halo = use_halo
        self._use_halo_pair = use_halo_pair
        self._use_split = use_split
        self._dummy_halo = dummy_halo

        def _execute(state, sched):
            recipes, levels, write_agents, halo, rows = sched
            if use_split and rows is not None:
                state, n_waves, shipped = _exec_split(
                    state, recipes, levels, write_agents, rows)
                self._win_comm.append(("split", shipped, n_waves))
                return state, n_waves
            state, n_waves = _exec_mono(
                state, recipes, levels, write_agents,
                halo if halo is not None else dummy_halo)
            self._win_comm.append(
                ("halo", halo_width, n_waves) if use_halo
                else ("full", n_pad, n_waves))
            return state, n_waves

        def _execute_pair(state, cur, lv_a, nxt, lv_b):
            rec_a, _, _, (wa_a, halo_a, rows_a) = cur
            rec_b, _, _, (wa_b, halo_b, rows_b) = nxt
            if use_split and rows_a is not None:
                state, n_waves, lv_b, shipped = _exec_pair_split(
                    state, rec_a, lv_a, wa_a, rec_b, lv_b, wa_b,
                    rows_a, rows_b)
                self._win_comm.append(("split", shipped, n_waves))
                return state, n_waves, lv_b
            halo = (pair_halo(halo_a, halo_b) if halo_a is not None
                    else dummy_halo)
            state, n_waves, lv_b = _exec_pair_mono(
                state, rec_a, lv_a, wa_a, rec_b, lv_b, wa_b, halo)
            self._win_comm.append(
                ("pair", 2 * halo_width, n_waves) if use_halo_pair
                else ("full", n_pad, n_waves))
            return state, n_waves, lv_b

        def _execute_drain(state, cur, lv):
            # partnerless drain (last / only window): the barrier
            # dispatcher re-splits by the current (possibly rebased)
            # levels — drained tasks carry level -1 and gather nothing
            wa, halo_idx, rows = cur[3]
            return _execute(state, (cur[0], lv, wa, halo_idx, rows))

        self._execute = _execute
        self._execute_pair = _execute_pair
        self._execute_drain = _execute_drain
        self._n_agents, self._n_pad = n_agents, n_pad
        # layout facts the tracer's per-wave comm attribution reads
        # (repro/obs — only touched when a tracer is installed)
        self._shard_n = shard_n
        self._halo_width = halo_width
        # the monolithic per-wave reference the split is measured against
        # (the mode that dominates the run: pair width for overlapped
        # runs — the final drain ships the single-window halo, slightly
        # less than reported — plain window halo otherwise; padded N
        # when the monolithic ladder itself would replicate)
        if self.overlap:
            self._gather_rows = 2 * halo_width if use_halo_pair else n_pad
        else:
            self._gather_rows = halo_width if use_halo else n_pad
        self._built_for = n_agents

    # ------------------------------------------------------- state hooks
    def _prepare_state(self, state):
        leaves = jax.tree_util.tree_leaves(state)
        assert leaves, "empty state"
        n = leaves[0].shape[0]
        assert all(x.shape[0] == n for x in leaves), (
            "sharded engine expects every state leaf to lead with the "
            f"agent axis; got shapes {[x.shape for x in leaves]}")
        self._build(n)
        n_pad = self._n_pad
        # per-agent-row bytes across leaves -> comm accounting for stats
        self._row_bytes = sum(
            x.dtype.itemsize * int(x.size) // n for x in leaves)
        self._full_bytes = n_pad * self._row_bytes
        self._win_comm = []
        padded = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, [(0, n_pad - n)] + [(0, 0)] * (x.ndim - 1)),
            state)
        return jax.device_put(padded, agent_state_shardings(padded, self.mesh))

    def _finalize_state(self, state):
        return jax.tree_util.tree_map(
            lambda x: x[:self._n_agents], state)

    def _extend_stats(self, stats: dict) -> dict:
        stats["n_devices"] = self.n_devices
        # the comm ledger holds one entry per executed window / fused
        # drain: "split" entries carry the window's total shipped rows
        # (the chunk ranges of its executed waves), monolithic entries
        # the static per-wave width. Converting the wave counts here is
        # the run's existing final host sync — nothing new blocks.
        ledger = [(kind, int(r), int(w)) for kind, r, w in self._win_comm]
        total_rows = sum(r if kind == "split" else r * w
                        for kind, r, w in ledger)
        waves = max(int(stats["total_waves"]), 1)
        rb = self._row_bytes
        mean_rows = total_rows / waves
        split_used = any(kind == "split" for kind, _, _ in ledger)
        stats["halo"] = any(kind in ("split", "halo", "pair")
                            for kind, _, _ in ledger)
        stats["halo_split"] = split_used
        # per-window layout composition — e.g. an overlapped run whose
        # pair halo tripped the width guard still drains its final
        # window through the single-window halo: {"full": 4, "halo": 1}
        modes: dict = {}
        for kind, _, _ in ledger:
            modes[kind] = modes.get(kind, 0) + 1
        stats["comm_modes"] = modes
        # rows/bytes actually delivered to each device per wave (mean
        # over executed waves — the split rung varies per wave), plus the
        # monolithic window/pair-halo reference it is measured against
        stats["per_wave_gather_rows"] = int(round(mean_rows))
        stats["per_wave_comm_bytes"] = int(round(mean_rows * rb))
        stats["full_state_bytes"] = int(self._full_bytes)
        stats["comm_bytes_total"] = int(total_rows * rb)
        stats["per_wave_split_rows"] = (round(mean_rows, 2) if split_used
                                        else None)
        if self.halo:
            stats["window_halo_rows"] = int(self._gather_rows)
            stats["window_halo_bytes"] = int(self._gather_rows * rb)
            stats["comm_reduction_vs_window_halo"] = (
                round(stats["window_halo_bytes"]
                      / stats["per_wave_comm_bytes"], 2)
                if stats["per_wave_comm_bytes"] else None)
        else:
            stats["window_halo_rows"] = None
            stats["window_halo_bytes"] = None
            stats["comm_reduction_vs_window_halo"] = None
        return stats

    # ------------------------------------------------------ compiled costs
    def _cost_targets(self, base_key, state):
        if not self._jit:
            return None
        recipes, levels, write_agents, halo, rows = self._schedule(
            base_key, 0, self.window)
        if self._use_split and rows is not None:
            return [("execute_split", self._jit_execs["split"],
                     (state, recipes, levels, write_agents, rows))]
        h = halo if halo is not None else self._dummy_halo
        return [("execute_window", self._jit_execs["mono"],
                 (state, recipes, levels, write_agents, h))]

    def comm_iteration_counts(self, stats: dict) -> dict[int, int]:
        """Executed dynamic-loop iterations per nesting depth, from the
        runtime comm ledger of the run that produced ``stats``: depth 1
        is the wave loop (total executed waves), depth 2 the split rung's
        chunk loop nested inside it (total chunk gathers = shipped rows /
        chunk). This is the resolution map for the HLO collectives
        ``compiled_costs`` parses (their per-iteration bytes × these
        counts must reproduce ``comm_bytes_total`` — the cross-check)."""
        chunk_iters = sum(int(r) // self.chunk
                          for kind, r, _ in self._win_comm
                          if kind == "split")
        return {1: int(stats["total_waves"]), 2: chunk_iters}

    # ------------------------------------------------------------ tracing
    # Reached only with a tracer installed (repro.obs) — the comm ledger
    # entry appended by the window's executor names the rung, and the
    # schedule's replicated level/row/write-target arrays reproduce the
    # per-wave shipped volume host-side (the split math below mirrors
    # ``wave_halo_split``: valid row slots per wave, ceil'd to chunks).

    _RUNG_NAMES = {"split": "split", "halo": "window_halo",
                   "pair": "pair_halo", "full": "full_state"}

    def _trace_parts(self, sched, levels=None):
        if levels is None:
            _, lv, wa, _, rows = sched          # barrier schedule
        else:
            lv = levels                          # overlapped: re-leveled
            wa, _, rows = sched[3]
        return lv, wa, rows

    def _trace_execute_args(self):
        if not self._win_comm:
            return {}
        kind, _, _ = self._win_comm[-1]
        return {"rung": self._RUNG_NAMES[kind], "n_devices": self.n_devices}

    def _trace_wave_comm(self, np_parts, n_waves):
        import numpy as np

        if not self._win_comm:
            return None
        kind = self._win_comm[-1][0]
        rung = self._RUNG_NAMES[kind]
        if kind == "split":
            per_wave = np.zeros(n_waves, np.int64)
            for lv, _, rows in np_parts:
                if rows is None:
                    continue
                ok = (lv >= 0) & (lv < n_waves)
                np.add.at(per_wave, lv[ok], (rows[ok] >= 0).sum(axis=1))
            per_wave = -(-per_wave // self.chunk) * self.chunk
        else:
            width = {"halo": self._halo_width,
                     "pair": 2 * self._halo_width,
                     "full": self._n_pad}[kind]
            per_wave = np.full(n_waves, width, np.int64)
        # per-device owned-task counts (a task runs on every device whose
        # row block holds one of its write targets) -> load imbalance
        owned = np.zeros((n_waves, self.n_devices), np.int64)
        for lv, wa, _ in np_parts:
            if wa is None:
                continue
            dev = np.where(wa >= 0, wa // self._shard_n, -1)
            for i in np.nonzero((lv >= 0) & (lv < n_waves))[0]:
                devs = np.unique(dev[i])
                owned[lv[i], devs[devs >= 0]] += 1
        rb = self._row_bytes
        return [{"rung": rung, "rows": int(r), "bytes": int(r) * rb,
                 "owned": owned[w].tolist()}
                for w, r in enumerate(per_wave)]


@register_engine
class ShardedWindowHaloEngine(ShardedEngine):
    """The monolithic window/pair-halo layout (the PR-3/4 behavior): the
    whole halo row list is gathered every wave. Kept as the registered
    middle rung of the comm ladder — and as the baseline the per-wave
    split's comm stats (``comm_reduction_vs_window_halo``) are measured
    against."""

    name = "sharded_window_halo"
    split = False


@register_engine
class ShardedReplicatedEngine(ShardedEngine):
    """The historic full-state layout, kept as an explicit registry
    fallback (and as the measurement baseline the halo engines' comm
    stats are compared against)."""

    name = "sharded_replicated"
    halo = False


@register_engine
class ShardedOverlapEngine(ShardedEngine):
    """``sharded`` with cross-window overlap on by default: fused tail/
    head waves with per-fused-wave slab gathers (pair-halo gather on the
    monolithic rung). The plain ``sharded`` engine stays the registered
    barrier fallback."""

    name = "sharded_overlap"
    default_overlap = True
