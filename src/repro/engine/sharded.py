"""Multi-device wavefront engine: waves sharded over the agent axis.

First step across the device boundary (ROADMAP: "shard the wavefront
engine"), following the window-local replication layout:

  * **agent state** — every state leaf leads with the agent axis; leaves
    are sharded into contiguous row blocks over a 1-D ``("agents",)``
    mesh (padded up when the device count does not divide N). Sharded
    state buffers are donated from window to window.
  * **window-local objects** — recipes, validity, the conflict matrix and
    the wave levels are O(W)/O(W²) *per-window* objects, so they stay
    replicated: scheduling runs once (conflict kernel + levels kernel,
    backend auto-detected) and its outputs are broadcast to the mesh.

Per wave, inside ``shard_map``:

  1. ``all_gather`` the state shards into the full agent state (the wave
     reads arbitrary neighbors, so reads need the whole state);
  2. restrict the wave mask to *owned* tasks — via the model's
     ``task_write_agents`` contract, a task is executed on every device
     whose row block contains at least one of its write targets (models
     without the contract run every task everywhere: redundant compute,
     identical result);
  3. run the model's vectorized ``execute_wave`` on the gathered state;
  4. keep only the local row block of the result.

Every device therefore applies exactly the updates that land in its rows,
and the union over devices is exactly the single-device wave — the engine
is bit-exact vs the sequential oracle under the strict rule
(property-tested under 8 virtual devices).

The ``WindowedEngine`` loop double-buffers windows: window t+1's schedule
is dispatched before the engine blocks on window t's waves.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    AGENT_AXIS as AXIS,
    agent_state_shardings,
    agents_mesh,
)
from repro.engine.base import WindowedEngine, register_engine
from repro.utils.compat import shard_map


@register_engine
class ShardedEngine(WindowedEngine):
    name = "sharded"

    def __init__(self, model, *, window: int = 256, strict: bool = True,
                 devices=None, jit: bool = True):
        super().__init__(model, window=window, strict=strict)
        self.mesh = agents_mesh(devices)
        self.n_devices = self.mesh.devices.size
        self._jit = jit
        self._built_for: int | None = None  # n_agents the fns were built for

        def _schedule(base_key, start, count):
            recipes, _, levels = self._schedule_window(base_key, start, count)
            return recipes, levels, model.task_write_agents(recipes)

        self._schedule = jax.jit(_schedule) if jit else _schedule

    # ------------------------------------------------------------ build
    def _build(self, n_agents: int):
        """Compile the sharded window executor for one agent count."""
        if self._built_for == n_agents:
            return
        model, d = self.model, self.n_devices
        n_pad = -(-n_agents // d) * d
        shard_n = n_pad // d

        def _pad(x):
            return jnp.pad(x, [(0, n_pad - n_agents)]
                           + [(0, 0)] * (x.ndim - 1))

        def window_local(local_state, recipes, levels, write_agents):
            # runs per-device inside shard_map; local leaves are [N/d, ...]
            lo = jax.lax.axis_index(AXIS) * shard_n
            n_waves = jnp.max(levels) + 1

            def body(carry):
                w, loc = carry
                full = jax.tree_util.tree_map(
                    lambda x: jax.lax.all_gather(
                        x, AXIS, axis=0, tiled=True)[:n_agents], loc)
                mask = levels == w
                if write_agents is not None:
                    owned = jnp.any(
                        (write_agents >= lo) & (write_agents < lo + shard_n),
                        axis=-1)
                    mask = mask & owned
                new = model.execute_wave(full, recipes, mask)
                loc = jax.tree_util.tree_map(
                    lambda x: jax.lax.dynamic_slice_in_dim(
                        _pad(x), lo, shard_n, axis=0), new)
                return w + 1, loc

            _, local_state = jax.lax.while_loop(
                lambda c: c[0] < n_waves, body,
                (jnp.int32(0), local_state))
            return local_state, n_waves

        window_sharded = shard_map(
            window_local, mesh=self.mesh,
            in_specs=(P(AXIS), P(), P(), P()),
            out_specs=(P(AXIS), P()),
            check_vma=False)

        def _execute(state, sched):
            recipes, levels, write_agents = sched
            return window_sharded(state, recipes, levels, write_agents)

        self._execute = (jax.jit(_execute, donate_argnums=(0,))
                         if self._jit else _execute)
        self._n_agents, self._n_pad = n_agents, n_pad
        self._built_for = n_agents

    # ------------------------------------------------------- state hooks
    def _prepare_state(self, state):
        leaves = jax.tree_util.tree_leaves(state)
        assert leaves, "empty state"
        n = leaves[0].shape[0]
        assert all(x.shape[0] == n for x in leaves), (
            "sharded engine expects every state leaf to lead with the "
            f"agent axis; got shapes {[x.shape for x in leaves]}")
        self._build(n)
        n_pad = self._n_pad
        padded = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, [(0, n_pad - n)] + [(0, 0)] * (x.ndim - 1)),
            state)
        return jax.device_put(padded, agent_state_shardings(padded, self.mesh))

    def _finalize_state(self, state):
        return jax.tree_util.tree_map(
            lambda x: x[:self._n_agents], state)

    def _extend_stats(self, stats: dict) -> dict:
        stats["n_devices"] = self.n_devices
        return stats
