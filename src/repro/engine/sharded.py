"""Multi-device wavefront engine: waves sharded over the agent axis.

Two communication layouts share one engine body:

**Halo exchange** (``sharded``, the default) — the paper's protocol only
pays off when per-wave work *and communication* stay proportional to the
localized update footprint. Every state leaf leads with the agent axis
and is sharded into contiguous row blocks over a 1-D ``("agents",)``
mesh. At schedule time (replicated, so no extra comm) the engine derives
the window's *halo*: the flattened list of state rows any task reads or
writes, from the model's ``task_read_agents`` / ``task_write_agents``
contracts — degree-bounded, padded to the static width W·(nr+nw). Per
wave, inside ``shard_map``:

  1. gather exactly the halo rows: each row has a unique owner shard;
     owners contribute, one ``psum`` over the agent axis delivers the
     rows everywhere — O(halo) values per device instead of the
     all_gather's O(N);
  2. scatter them into a full-size scratch buffer and refresh the local
     row block from the authoritative local shard (a local copy, no
     comm) — every row an owned task can read is now current; rows
     outside halo ∪ local block stay stale zeros and are provably never
     read;
  3. restrict the wave mask to *owned* tasks (a task executes on every
     device whose row block contains one of its write targets) and run
     the model's vectorized ``execute_wave`` on the scratch;
  4. keep only the local row block of the result — writes land directly
     on their owners, so no write scatter is communicated at all.

**Replicated all_gather** (``sharded_replicated``, the fallback) — the
historic layout: per wave, ``all_gather`` the state shards into the full
agent state and execute on that. Models that do not declare the
read/write row contracts route here automatically, as does any run whose
halo would not beat the full state (halo width >= N).

**Cross-window overlap** (``overlap=True`` / ``sharded_overlap``): the
window boundary stops draining at a barrier — window k+1's head waves
execute fused with window k's tail (see ``WindowedEngine``). Per fused
wave the gather must deliver every row *either* window can touch, so the
schedule carries the pair halo: the union of both windows' read ∪ write
rows (``distributed.sharding.pair_halo``, static width 2·W·(nr+nw)); the
halo-vs-full-state decision and the comm accounting use that doubled
width. Each fused wave gathers once, executes window k's owned tasks at
that level, then window k+1's on the same scratch — legal because the
carry frontier guarantees a fused wave never holds conflicting tasks,
so neither window's reads overlap the other's same-wave writes.

Window-local objects (recipes, validity, conflict matrix, wave levels)
are O(W)/O(W²) and stay replicated in both modes; scheduling runs once
and its outputs broadcast to the mesh. All modes are bit-exact vs the
sequential oracle under the strict rule (property-tested under 8 virtual
devices), and report their per-wave comm volume in ``run`` stats
(``per_wave_comm_bytes`` vs ``full_state_bytes``).

The ``WindowedEngine`` loop double-buffers windows: window t+1's schedule
is dispatched before the engine blocks on window t's waves.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    AGENT_AXIS as AXIS,
    agent_state_shardings,
    agents_mesh,
    halo_gather,
    halo_scatter,
    pair_halo,
    window_halo,
)
from repro.engine.base import WindowedEngine, register_engine
from repro.utils.compat import shard_map


@register_engine
class ShardedEngine(WindowedEngine):
    name = "sharded"

    #: None = probe the model for the halo contracts; False = always
    #: replicate (the ``sharded_replicated`` registry entry).
    halo: bool | None = None

    def __init__(self, model, *, window: int = 256, strict: bool = True,
                 devices=None, jit: bool = True, halo: bool | None = None,
                 overlap: bool | None = None):
        super().__init__(model, window=window, strict=strict,
                         overlap=overlap)
        self.mesh = agents_mesh(devices)
        self.n_devices = self.mesh.devices.size
        self._jit = jit
        self._built_for: int | None = None  # n_agents the fns were built for
        if halo is not None:
            self.halo = halo
        self._halo_slots = 0
        if self.halo is None or self.halo:
            # one-shot host probe: the halo layout needs both row contracts
            probe = model.create_tasks(jax.random.key(0), 0, 1)
            reads = model.task_read_agents(probe)
            writes = model.task_write_agents(probe)
            if self.halo is None:
                self.halo = reads is not None and writes is not None
            elif reads is None or writes is None:
                raise ValueError(
                    f"halo=True needs {type(model).__name__} to implement "
                    "both task_read_agents and task_write_agents; use the "
                    "'sharded_replicated' engine (or halo=None auto-probe) "
                    "for models without the row contracts")
            if self.halo:
                self._halo_slots = reads.shape[-1] + writes.shape[-1]

        def _schedule(base_key, start, count):
            recipes, _, levels = self._schedule_window(base_key, start, count)
            writes = model.task_write_agents(recipes)
            halo_idx = (window_halo(model.task_read_agents(recipes), writes)
                        if self.halo else None)
            return recipes, levels, writes, halo_idx

        self._schedule = jax.jit(_schedule) if jit else _schedule

        def _schedule_ov(base_key, start, count):
            recipes, valid, conf = self._schedule_window_ov(
                base_key, start, count)
            writes = model.task_write_agents(recipes)
            halo_idx = (window_halo(model.task_read_agents(recipes), writes)
                        if self.halo else None)
            return recipes, valid, conf, (writes, halo_idx)

        self._schedule_ov = jax.jit(_schedule_ov) if jit else _schedule_ov

    # ------------------------------------------------------------ build
    def _build(self, n_agents: int):
        """Compile the sharded window executor for one agent count."""
        if self._built_for == n_agents:
            return
        model, d = self.model, self.n_devices
        n_pad = -(-n_agents // d) * d
        shard_n = n_pad // d
        halo_width = self.window * self._halo_slots
        # degenerate halo (>= full state): replication ships fewer bytes.
        # The barrier/drain executor decides on the single-window width;
        # fused waves gather the union of both windows' halos, so the
        # pair executor decides on the doubled width independently (a
        # window size whose single halo wins can lose once doubled).
        use_halo = self.halo and halo_width < n_agents
        use_halo_pair = self.halo and 2 * halo_width < n_agents

        def _pad(x):
            return jnp.pad(x, [(0, n_pad - n_agents)]
                           + [(0, 0)] * (x.ndim - 1))

        def read_view(loc, halo, local_rows, use):
            """Every row the wave's owned tasks may read, fresh."""
            if not use:
                return jax.tree_util.tree_map(
                    lambda x: jax.lax.all_gather(
                        x, AXIS, axis=0, tiled=True)[:n_agents], loc)

            def one(x):
                g = halo_gather(x, halo, shard_n=shard_n)
                scratch = jnp.zeros((n_agents,) + x.shape[1:], x.dtype)
                scratch = halo_scatter(scratch, halo, g)
                # local block is authoritative — refresh it so the
                # end-of-wave slice keeps unwritten rows exact
                return scratch.at[local_rows].set(x, mode="drop")
            return jax.tree_util.tree_map(one, loc)

        def owned_mask(levels, write_agents, w, lo):
            mask = levels == w
            if write_agents is not None:
                owned = jnp.any(
                    (write_agents >= lo) & (write_agents < lo + shard_n),
                    axis=-1)
                mask = mask & owned
            return mask

        def keep_local(new, lo):
            return jax.tree_util.tree_map(
                lambda x: jax.lax.dynamic_slice_in_dim(
                    _pad(x), lo, shard_n, axis=0), new)

        def window_local(local_state, recipes, levels, write_agents, halo):
            # runs per-device inside shard_map; local leaves are [N/d, ...]
            lo = jax.lax.axis_index(AXIS) * shard_n
            local_rows = lo + jnp.arange(shard_n)
            n_waves = jnp.max(levels) + 1

            def body(carry):
                w, loc = carry
                full = read_view(loc, halo, local_rows, use_halo)
                new = model.execute_wave(
                    full, recipes, owned_mask(levels, write_agents, w, lo))
                return w + 1, keep_local(new, lo)

            _, local_state = jax.lax.while_loop(
                lambda c: c[0] < n_waves, body,
                (jnp.int32(0), local_state))
            return local_state, n_waves

        def window_pair_local(local_state, rec_a, lv_a, wa_a,
                              rec_b, lv_b, wa_b, halo):
            # fused drain of window k (a) overlapped with window k+1 (b);
            # halo is the pair union, so one gather serves both windows
            lo = jax.lax.axis_index(AXIS) * shard_n
            local_rows = lo + jnp.arange(shard_n)
            n_waves = jnp.max(lv_a) + 1

            def body(carry):
                w, loc = carry
                full = read_view(loc, halo, local_rows, use_halo_pair)
                new = model.execute_wave(
                    full, rec_a, owned_mask(lv_a, wa_a, w, lo))
                # b's reads never overlap a's same-wave writes (the carry
                # frontier forbids conflicts inside a fused wave), so
                # executing b on a's output scratch is exact
                new = model.execute_wave(
                    new, rec_b, owned_mask(lv_b, wa_b, w, lo))
                return w + 1, keep_local(new, lo)

            _, local_state = jax.lax.while_loop(
                lambda c: c[0] < n_waves, body,
                (jnp.int32(0), local_state))
            return local_state, n_waves

        window_sharded = shard_map(
            window_local, mesh=self.mesh,
            in_specs=(P(AXIS), P(), P(), P(), P()),
            out_specs=(P(AXIS), P()),
            check_vma=False)

        window_pair_sharded = shard_map(
            window_pair_local, mesh=self.mesh,
            in_specs=(P(AXIS), P(), P(), P(), P(), P(), P(), P()),
            out_specs=(P(AXIS), P()),
            check_vma=False)

        def _execute(state, sched):
            recipes, levels, write_agents, halo = sched
            if halo is None:   # replicated mode schedules carry no halo
                halo = jnp.full((1,), -1, jnp.int32)
            return window_sharded(state, recipes, levels, write_agents, halo)

        def _execute_pair(state, cur, lv_a, nxt, lv_b):
            rec_a, _, _, (wa_a, halo_a) = cur
            rec_b, _, _, (wa_b, halo_b) = nxt
            halo = (pair_halo(halo_a, halo_b) if halo_a is not None
                    else jnp.full((1,), -1, jnp.int32))
            state, n_waves = window_pair_sharded(
                state, rec_a, lv_a, wa_a, rec_b, lv_b, wa_b, halo)
            # rebase the next window onto the new level clock; executed
            # (and invalid) tasks drop to -1
            lv_b = jnp.where(lv_b >= n_waves, lv_b - n_waves, -1)
            return state, n_waves, lv_b

        self._execute = (jax.jit(_execute, donate_argnums=(0,))
                         if self._jit else _execute)
        self._execute_pair = (jax.jit(_execute_pair, donate_argnums=(0,))
                              if self._jit else _execute_pair)
        # partnerless drain (last / only window): route through the
        # barrier executor — single-window halo width, no fused waves
        self._execute_drain = lambda state, cur, lv: self._execute(
            state, (cur[0], lv, cur[3][0], cur[3][1]))
        self._n_agents, self._n_pad = n_agents, n_pad
        # stats report the mode that dominates the run: fused pair waves
        # for overlapped runs (the final drain ships the single-window
        # halo, slightly less than reported), plain windows otherwise
        if self.overlap:
            self._halo_active = bool(use_halo_pair)
            self._gather_rows = 2 * halo_width if use_halo_pair else n_pad
        else:
            self._halo_active = bool(use_halo)
            self._gather_rows = halo_width if use_halo else n_pad
        self._built_for = n_agents

    # ------------------------------------------------------- state hooks
    def _prepare_state(self, state):
        leaves = jax.tree_util.tree_leaves(state)
        assert leaves, "empty state"
        n = leaves[0].shape[0]
        assert all(x.shape[0] == n for x in leaves), (
            "sharded engine expects every state leaf to lead with the "
            f"agent axis; got shapes {[x.shape for x in leaves]}")
        self._build(n)
        n_pad = self._n_pad
        # per-agent-row bytes across leaves -> comm accounting for stats
        row_bytes = sum(x.dtype.itemsize * int(x.size) // n for x in leaves)
        self._comm_bytes = self._gather_rows * row_bytes
        self._full_bytes = n_pad * row_bytes
        padded = jax.tree_util.tree_map(
            lambda x: jnp.pad(x, [(0, n_pad - n)] + [(0, 0)] * (x.ndim - 1)),
            state)
        return jax.device_put(padded, agent_state_shardings(padded, self.mesh))

    def _finalize_state(self, state):
        return jax.tree_util.tree_map(
            lambda x: x[:self._n_agents], state)

    def _extend_stats(self, stats: dict) -> dict:
        stats["n_devices"] = self.n_devices
        stats["halo"] = self._halo_active
        # rows delivered to each device per wave (halo list vs full state)
        # and the matching payload bytes; comm_bytes_total accumulates the
        # per-device receive volume over every executed wave. Overlapped
        # runs gather the pair halo (2·W·slots rows) per fused wave.
        stats["per_wave_gather_rows"] = int(self._gather_rows)
        stats["per_wave_comm_bytes"] = int(self._comm_bytes)
        stats["full_state_bytes"] = int(self._full_bytes)
        stats["comm_bytes_total"] = int(self._comm_bytes) * stats["total_waves"]
        return stats


@register_engine
class ShardedReplicatedEngine(ShardedEngine):
    """The historic full-state layout, kept as an explicit registry
    fallback (and as the measurement baseline the halo engine's comm
    stats are compared against)."""

    name = "sharded_replicated"
    halo = False


@register_engine
class ShardedOverlapEngine(ShardedEngine):
    """``sharded`` with cross-window overlap on by default: fused tail/
    head waves with the pair-halo gather. The plain ``sharded`` engine
    stays the registered barrier fallback."""

    name = "sharded_overlap"
    default_overlap = True
