"""Execution-engine interface and registry.

An *engine* binds a ``MABSModel`` to a way of actually running its task
chain: strictly sequentially (the oracle), by vectorized waves on one
device, or by waves sharded over the agent axis of a device mesh. All
engines consume the identical task stream (``create_tasks`` keyed by the
global chain index) and — by the protocol's sequential-equivalence
argument — produce bit-identical state for the strict hazard rule, so the
choice of engine is a pure performance decision.

Registry:

    from repro.engine import make_engine
    eng = make_engine("sharded", model, window=256)
    state, stats = eng.run(state, total_tasks, seed=0)

``WindowedEngine`` additionally fixes the streaming structure shared by
the wavefront and sharded engines: windows of W tasks, each scheduled
(conflict matrix + wave levels, both replicated window-local objects) and
then executed wave by wave — with a double-buffered *window pipeline*:
the schedule for window t+1 is dispatched before the engine blocks on
window t's execution, so the O(W²) record check of the next window
overlaps the wave execution of the current one on the device queue.
"""
from __future__ import annotations

import abc
from contextlib import nullcontext
from typing import Any, Type

import jax
import jax.numpy as jnp

from repro.obs.stats import finalize_stats
from repro.obs.trace import TID_COMM, current_tracer

ENGINES: dict[str, Type["Engine"]] = {}


def register_engine(cls: Type["Engine"]) -> Type["Engine"]:
    """Class decorator: add an Engine subclass to the registry."""
    assert cls.name not in ENGINES or ENGINES[cls.name] is cls, cls.name
    ENGINES[cls.name] = cls
    return cls


def get_engine(name: str) -> Type["Engine"]:
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(ENGINES)}"
        ) from None


def make_engine(name: str, model, **kwargs) -> "Engine":
    return get_engine(name)(model, **kwargs)


class Engine(abc.ABC):
    """One way of executing a model's task chain."""

    #: registry key
    name: str = "engine"

    #: default for the cross-window overlap knob (the ``*_overlap``
    #: registry entries flip it; ``overlap=None`` keeps the class default)
    default_overlap: bool = False

    def __init__(self, model, *, window: int = 256, strict: bool = True,
                 overlap: bool | None = None):
        self.model = model
        self.window = int(window)
        self.strict = strict
        self.overlap = (self.default_overlap if overlap is None
                        else bool(overlap))

    @abc.abstractmethod
    def run(self, state: Any, total_tasks: int, *, seed: int = 0
            ) -> tuple[Any, dict]:
        """Execute total_tasks tasks from the chain; returns (state, stats).

        stats always carries ``total_tasks``, ``n_windows``,
        ``total_waves`` and ``mean_parallelism``; engines may add keys.
        """


class WindowedEngine(Engine):
    """Shared streaming loop: schedule window t+1 while window t executes.

    Subclasses provide
      * ``_schedule(base_key, start, count)`` — create + schedule one
        window; returns an opaque pytree (dispatched asynchronously), and
      * ``_execute(state, sched)`` — execute one scheduled window;
        returns (state, n_waves),
    plus optional ``_prepare_state`` / ``_finalize_state`` hooks (e.g. the
    sharded engine pads and device_puts the agent axis there). The run
    loop never blocks between windows: the only host sync is the final
    stats reduction after the last window was dispatched.

    **Cross-window overlap** (``overlap=True``, or the ``*_overlap``
    registry entries): the window boundary stops being a conservative
    barrier. When window k+1 is scheduled, the loop computes the
    carry-over conflict frontier between window k's not-yet-drained tail
    and window k+1's tasks (``records.cross_window_conflicts`` — the
    rectangular [W_next, W_tail] block through the conflict kernel — and
    ``records.carry_frontier``), then re-levels window k+1 with that
    frontier as a per-task floor (``wave_levels(base=carry)``). Execution
    proceeds in *fused* waves: each wave of window k's drain also runs
    the window k+1 tasks whose (floored) level matches, so independent
    head waves of k+1 start while k's tail drains. Tasks sharing a fused
    wave never conflict — a cross conflict (i, j) forces
    ``level_next[i] >= level_tail[j] + 1`` — so bit-exactness vs the
    sequential oracle is preserved (the differential harness pins it).
    At most two windows are ever in flight: pair step k drains window k
    completely, so window k+2 only needs the frontier against k+1's
    remainder. Overlapped subclasses provide
      * ``_schedule_ov(base_key, start, count)`` — returns
        ``(recipes, valid, conf, extra)`` (conflict matrix kept for the
        carry re-leveling; ``extra`` is engine-specific), and
      * ``_execute_pair(state, cur, lv_cur, nxt, lv_nxt)`` — runs the
        fused waves that drain ``cur``; returns
        ``(state, n_waves, lv_nxt_shifted)`` where the shifted levels
        mark executed tasks -1 and rebase the rest to the new clock.
    Engines without the pair hooks fall back to the barrier loop.
    """

    #: overlapped-mode hooks; None = barrier-only engine. ``_execute_drain``
    #: drains a window with no live partner (the run's last window, or a
    #: single-window run) through the engine's barrier executor — no
    #: dummy-partner execute_wave calls, no pair-halo gather.
    _schedule_ov = None
    _execute_pair = None
    _execute_drain = None

    def _prepare_state(self, state):
        return state

    def _finalize_state(self, state):
        return state

    def _schedule_window(self, base_key, start, count):
        """The shared scheduling recipe: create one window of tasks and
        reduce it to wave levels (conflict + levels kernels, backend
        auto-detected). Returns (recipes, valid, levels)."""
        from repro.core.records import wave_levels

        recipes, valid, conf = self._schedule_window_ov(
            base_key, start, count)
        return recipes, valid, wave_levels(conf, valid)

    def _schedule_window_ov(self, base_key, start, count):
        """Overlap-mode scheduling recipe: like ``_schedule_window`` but
        the conflict matrix is kept (the boundary step re-levels against
        the carry frontier). Returns (recipes, valid, conf)."""
        from repro.core.records import window_conflicts

        recipes = self.model.create_tasks(base_key, start, self.window)
        valid = jnp.arange(self.window) < count
        conf = window_conflicts(self.model, recipes, valid,
                                strict=self.strict)
        return recipes, valid, conf

    def _schedule(self, base_key, start, count):  # pragma: no cover - abstract
        raise NotImplementedError

    def _execute(self, state, sched):  # pragma: no cover - abstract
        raise NotImplementedError

    # ------------------------------------------------------ compiled costs
    def _cost_targets(self, base_key, state):
        """``(name, jitted_fn, example_args)`` triples for the engine's
        jit-boundary window executors (the functions ``_execute``
        dispatches to), lowered AOT by ``compiled_costs``. ``state`` is
        already prepared (``_prepare_state`` has run, so the sharded
        executors are built). None = no AOT-lowerable executors (jit
        disabled, or no hook)."""
        return None

    def compiled_costs(self, state, *, seed: int = 0):
        """Compiled-cost telemetry of this engine's window executors:
        ``{name: repro.obs.costs.ExecutorCost}`` with cost_analysis
        FLOPs/bytes, the memory decomposition, and the HLO-parsed
        collective ops (classified by dynamic-loop depth — resolve
        against executed iteration counts, e.g. the sharded engine's
        ``comm_iteration_counts``). Lowering compiles but never runs, so
        ``state`` is not consumed. Returns None for engines/configs with
        no AOT-lowerable executors; overlapped runs dispatch the pair
        executors instead of these, so cost capture is barrier-mode only.
        """
        if self.overlap:
            return None
        from repro.obs.costs import executor_cost

        state = self._prepare_state(state)
        targets = self._cost_targets(jax.random.key(seed), state)
        if not targets:
            return None
        return {name: executor_cost(fn, *args, name=name)
                for name, fn, args in targets}

    # ------------------------------------------------------------- tracing
    #
    # Every hook below is reached only when a tracer is installed
    # (``repro.obs.trace.tracing()``); the untraced run loops guard on a
    # single ``current_tracer() is None`` check, so tracing off adds zero
    # host syncs to the hot path. With tracing on, span boundaries fence
    # with ``jax.block_until_ready`` — which deliberately serializes the
    # double-buffered window pipeline to attribute wall time to the
    # schedule vs execute halves (docs/observability.md).

    def _trace_parts(self, sched, levels=None):
        """(levels, write_agents, rows) of one window's schedule, for the
        per-wave trace attributes. ``levels`` overrides the schedule's
        own level vector (the overlapped loop re-levels and rebases).
        None disables per-wave spans for this engine."""
        return None

    def _trace_wave_comm(self, np_parts, n_waves):
        """Per-wave comm attributes (list of dicts with ``rung``/``rows``
        /``bytes`` and optionally ``owned`` per-device task counts), or
        None for engines that ship nothing (single device)."""
        return None

    def _trace_execute_args(self):
        """Extra args for a just-closed execute span (e.g. the sharded
        engine's comm-ladder rung)."""
        return {}

    def _dispatch_schedule(self, tr, base_key, start, count, *, index,
                           ov=False):
        """Dispatch one window's schedule, wrapped in a fenced span when
        tracing is on."""
        fn = self._schedule_ov if ov else self._schedule
        if tr is None:
            return fn(base_key, start, count)
        with tr.span("schedule", index=index, start=start, count=count):
            sched = fn(base_key, start, count)
            jax.block_until_ready(sched)
        return sched

    def _trace_window(self, tr, sp, parts, n_waves):
        """Emit per-wave spans (width-proportional attribution inside the
        closed execute span ``sp``) and per-wave ``halo_gather`` spans.
        ``parts`` holds one ``_trace_parts`` triple per live window (two
        for a fused pair drain)."""
        import numpy as np

        parts = [p for p in parts if p is not None]
        if n_waves <= 0 or not parts:
            return
        widths = np.zeros(n_waves, np.int64)
        np_parts = []
        for lv, wa, rows in parts:
            lv = np.asarray(lv)
            np_parts.append((lv,
                             None if wa is None else np.asarray(wa),
                             None if rows is None else np.asarray(rows)))
            sel = lv[(lv >= 0) & (lv < n_waves)]
            if sel.size:
                widths[:] += np.bincount(sel, minlength=n_waves)[:n_waves]
        comm = self._trace_wave_comm(np_parts, n_waves)
        window = sp.args.get("index")
        args = [{"window": window, "level": w, "width": int(widths[w])}
                for w in range(n_waves)]
        if comm is not None:
            for a, c in zip(args, comm):
                owned = c.pop("owned", None)
                if owned is not None:
                    a["owned"] = owned
        slots = tr.subdivide(sp, "wave", widths.tolist(), args)
        if comm is not None:
            for w, ((ts, dur), c) in enumerate(zip(slots, comm)):
                if c.get("rows"):
                    tr.complete("halo_gather", ts, dur, tid=TID_COMM,
                                window=window, level=w, attributed=True,
                                **c)

    # ------------------------------------------------- cross-window overlap
    def _make_boundary(self):
        """Jitted boundary step for one window transition k -> k+1:
        cross-window record check, carry frontier, floored re-leveling,
        and the per-boundary overlap statistics."""
        from repro.core.records import (
            carry_frontier,
            cross_window_conflicts,
            wave_levels,
        )

        model, strict, w = self.model, self.strict, self.window

        def boundary(rec_a, lv_a, rec_b, valid_b, conf_b):
            alive_a = lv_a >= 0          # window k's not-yet-drained tail
            cross = cross_window_conflicts(model, rec_a, alive_a,
                                           rec_b, valid_b, strict=strict)
            carry = carry_frontier(cross, lv_a)
            lv_b = wave_levels(conf_b, valid_b, base=carry)
            n_waves_a = jnp.max(lv_a) + 1
            # overlap depth: tail waves of k during which k+1 tasks run
            early = valid_b & (lv_b < n_waves_a)
            occ = jnp.zeros((w,), bool).at[
                jnp.where(early, lv_b, w)].set(True, mode="drop")
            n_valid = jnp.maximum(jnp.sum(valid_b), 1)
            bstats = (jnp.sum(occ),                              # depth
                      jnp.sum(early),                            # early tasks
                      jnp.sum(jnp.where(valid_b, carry, 0)) / n_valid,
                      jnp.max(jnp.where(valid_b, carry, 0), initial=0))
            return lv_b, bstats

        return jax.jit(boundary)

    def _levels0(self, conf, valid):
        """First window's levels (no predecessor -> no carry floor)."""
        if getattr(self, "_levels0_fn", None) is None:
            from repro.core.records import wave_levels

            self._levels0_fn = jax.jit(
                lambda c, v: wave_levels(c, v))
        return self._levels0_fn(conf, valid)

    def _run_overlapped(self, state: Any, total_tasks: int, *, seed: int = 0):
        tr = current_tracer()
        base_key = jax.random.key(seed)
        state = self._prepare_state(state)
        if getattr(self, "_boundary_fn", None) is None:
            self._boundary_fn = self._make_boundary()
        t = 0
        n_windows = 0
        wave_counts = []
        bstats = []
        run_cm = (tr.span("run", engine=self.name, window=self.window,
                          total_tasks=total_tasks, overlap=True)
                  if tr is not None else nullcontext())
        with run_cm:
            cur = self._dispatch_schedule(
                tr, base_key, 0, min(self.window, total_tasks),
                index=0, ov=True)
            lv = self._levels0(cur[2], cur[1])
            while t < total_tasks:
                k = min(self.window, total_tasks - t)
                if t + k < total_tasks:
                    # dispatch window k+1's schedule + boundary (cross
                    # block, carry frontier, floored levels) before
                    # blocking on the fused drain of window k — same
                    # double buffering as the barrier loop, now with the
                    # carry-over record check
                    nxt = self._dispatch_schedule(
                        tr, base_key, t + k,
                        min(self.window, total_tasks - t - k),
                        index=n_windows + 1, ov=True)
                    if tr is None:
                        lv_nxt, b = self._boundary_fn(cur[0], lv,
                                                      nxt[0], nxt[1], nxt[2])
                    else:
                        with tr.span("boundary", index=n_windows) as bsp:
                            lv_nxt, b = self._boundary_fn(
                                cur[0], lv, nxt[0], nxt[1], nxt[2])
                            jax.block_until_ready((lv_nxt, b))
                        bsp.args.update(
                            overlap_depth=int(b[0]), early_tasks=int(b[1]),
                            carry_mean=float(b[2]), carry_max=int(b[3]))
                    bstats.append(b)
                    if tr is None:
                        state, n_waves, lv_nxt = self._execute_pair(
                            state, cur, lv, nxt, lv_nxt)
                    else:
                        lv_pre = lv_nxt  # pre-rebase levels: wave widths
                        with tr.span("execute", index=n_windows, start=t,
                                     count=k, fused=True) as sp:
                            state, n_waves, lv_nxt = self._execute_pair(
                                state, cur, lv, nxt, lv_nxt)
                            jax.block_until_ready(state)
                            n_waves = int(n_waves)
                        sp.args["n_waves"] = n_waves
                        sp.args.update(self._trace_execute_args())
                        self._trace_window(
                            tr, sp, [self._trace_parts(cur, lv),
                                     self._trace_parts(nxt, lv_pre)],
                            n_waves)
                    cur, lv = nxt, lv_nxt
                else:
                    # last window: no partner — drain through the barrier
                    # executor (skips the empty-mask partner waves and,
                    # for the sharded engine, the doubled pair-halo
                    # gather)
                    if tr is None:
                        state, n_waves = self._execute_drain(state, cur, lv)
                    else:
                        with tr.span("execute", index=n_windows, start=t,
                                     count=k, drain=True) as sp:
                            state, n_waves = self._execute_drain(
                                state, cur, lv)
                            jax.block_until_ready(state)
                            n_waves = int(n_waves)
                        sp.args["n_waves"] = n_waves
                        sp.args.update(self._trace_execute_args())
                        self._trace_window(
                            tr, sp, [self._trace_parts(cur, lv)], n_waves)
                wave_counts.append(n_waves)
                n_windows += 1
                t += k
        total_waves = int(sum(int(w) for w in wave_counts))  # host sync here
        state = self._finalize_state(state)
        depths = [int(b[0]) for b in bstats]
        earlies = [int(b[1]) for b in bstats]
        cmeans = [float(b[2]) for b in bstats]
        cmaxs = [int(b[3]) for b in bstats]
        stats = {
            "total_tasks": total_tasks,
            "n_windows": n_windows,
            "total_waves": total_waves,
            "mean_parallelism": total_tasks / max(total_waves, 1),
            "overlap": True,
            "n_boundaries": len(bstats),
            "mean_overlap_depth": (sum(depths) / len(depths)
                                   if depths else 0.0),
            "max_overlap_depth": max(depths, default=0),
            "overlap_tasks_early": sum(earlies),
            "carry_frontier_mean": (sum(cmeans) / len(cmeans)
                                    if cmeans else 0.0),
            "carry_frontier_max": max(cmaxs, default=0),
        }
        return state, finalize_stats(self._extend_stats(stats))

    def run(self, state: Any, total_tasks: int, *, seed: int = 0):
        if self.overlap:
            # NB: only the schedule hook is checked here — engines may
            # defer building the pair executor until the state shape is
            # known (_prepare_state), as the sharded engine does
            if self._schedule_ov is None:
                raise ValueError(
                    f"engine {self.name!r} does not implement cross-window "
                    "overlap; use overlap=False (the barrier fallback)")
            return self._run_overlapped(state, total_tasks, seed=seed)
        tr = current_tracer()
        base_key = jax.random.key(seed)
        state = self._prepare_state(state)
        t = 0
        n_windows = 0
        wave_counts = []
        run_cm = (tr.span("run", engine=self.name, window=self.window,
                          total_tasks=total_tasks, overlap=False)
                  if tr is not None else nullcontext())
        with run_cm:
            nxt = self._dispatch_schedule(
                tr, base_key, 0, min(self.window, total_tasks), index=0)
            while t < total_tasks:
                k = min(self.window, total_tasks - t)
                cur = nxt
                if t + k < total_tasks:
                    # double buffering: dispatch window t+1's schedule
                    # (conflict matrix + levels) before blocking on window
                    # t's execution
                    nxt = self._dispatch_schedule(
                        tr, base_key, t + k,
                        min(self.window, total_tasks - t - k),
                        index=n_windows + 1)
                if tr is None:
                    state, n_waves = self._execute(state, cur)
                else:
                    with tr.span("execute", index=n_windows, start=t,
                                 count=k) as sp:
                        state, n_waves = self._execute(state, cur)
                        jax.block_until_ready(state)
                        n_waves = int(n_waves)
                    sp.args["n_waves"] = n_waves
                    sp.args.update(self._trace_execute_args())
                    self._trace_window(
                        tr, sp, [self._trace_parts(cur)], n_waves)
                wave_counts.append(n_waves)
                n_windows += 1
                t += k
        total_waves = int(sum(int(w) for w in wave_counts))  # host sync here
        state = self._finalize_state(state)
        stats = {
            "total_tasks": total_tasks,
            "n_windows": n_windows,
            "total_waves": total_waves,
            "mean_parallelism": total_tasks / max(total_waves, 1),
            "overlap": False,
        }
        return state, finalize_stats(self._extend_stats(stats))

    def _extend_stats(self, stats: dict) -> dict:
        return stats
