"""Execution-engine interface and registry.

An *engine* binds a ``MABSModel`` to a way of actually running its task
chain: strictly sequentially (the oracle), by vectorized waves on one
device, or by waves sharded over the agent axis of a device mesh. All
engines consume the identical task stream (``create_tasks`` keyed by the
global chain index) and — by the protocol's sequential-equivalence
argument — produce bit-identical state for the strict hazard rule, so the
choice of engine is a pure performance decision.

Registry:

    from repro.engine import make_engine
    eng = make_engine("sharded", model, window=256)
    state, stats = eng.run(state, total_tasks, seed=0)

``WindowedEngine`` additionally fixes the streaming structure shared by
the wavefront and sharded engines: windows of W tasks, each scheduled
(conflict matrix + wave levels, both replicated window-local objects) and
then executed wave by wave — with a double-buffered *window pipeline*:
the schedule for window t+1 is dispatched before the engine blocks on
window t's execution, so the O(W²) record check of the next window
overlaps the wave execution of the current one on the device queue.
"""
from __future__ import annotations

import abc
from typing import Any, Type

import jax
import jax.numpy as jnp

ENGINES: dict[str, Type["Engine"]] = {}


def register_engine(cls: Type["Engine"]) -> Type["Engine"]:
    """Class decorator: add an Engine subclass to the registry."""
    assert cls.name not in ENGINES or ENGINES[cls.name] is cls, cls.name
    ENGINES[cls.name] = cls
    return cls


def get_engine(name: str) -> Type["Engine"]:
    try:
        return ENGINES[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; registered: {sorted(ENGINES)}"
        ) from None


def make_engine(name: str, model, **kwargs) -> "Engine":
    return get_engine(name)(model, **kwargs)


class Engine(abc.ABC):
    """One way of executing a model's task chain."""

    #: registry key
    name: str = "engine"

    def __init__(self, model, *, window: int = 256, strict: bool = True):
        self.model = model
        self.window = int(window)
        self.strict = strict

    @abc.abstractmethod
    def run(self, state: Any, total_tasks: int, *, seed: int = 0
            ) -> tuple[Any, dict]:
        """Execute total_tasks tasks from the chain; returns (state, stats).

        stats always carries ``total_tasks``, ``n_windows``,
        ``total_waves`` and ``mean_parallelism``; engines may add keys.
        """


class WindowedEngine(Engine):
    """Shared streaming loop: schedule window t+1 while window t executes.

    Subclasses provide
      * ``_schedule(base_key, start, count)`` — create + schedule one
        window; returns an opaque pytree (dispatched asynchronously), and
      * ``_execute(state, sched)`` — execute one scheduled window;
        returns (state, n_waves),
    plus optional ``_prepare_state`` / ``_finalize_state`` hooks (e.g. the
    sharded engine pads and device_puts the agent axis there). The run
    loop never blocks between windows: the only host sync is the final
    stats reduction after the last window was dispatched.
    """

    def _prepare_state(self, state):
        return state

    def _finalize_state(self, state):
        return state

    def _schedule_window(self, base_key, start, count):
        """The shared scheduling recipe: create one window of tasks and
        reduce it to wave levels (conflict + levels kernels, backend
        auto-detected). Returns (recipes, valid, levels)."""
        from repro.core.records import wave_levels, window_conflicts

        recipes = self.model.create_tasks(base_key, start, self.window)
        valid = jnp.arange(self.window) < count
        conf = window_conflicts(self.model, recipes, valid,
                                strict=self.strict)
        return recipes, valid, wave_levels(conf, valid)

    def _schedule(self, base_key, start, count):  # pragma: no cover - abstract
        raise NotImplementedError

    def _execute(self, state, sched):  # pragma: no cover - abstract
        raise NotImplementedError

    def run(self, state: Any, total_tasks: int, *, seed: int = 0):
        base_key = jax.random.key(seed)
        state = self._prepare_state(state)
        t = 0
        n_windows = 0
        wave_counts = []
        nxt = self._schedule(base_key, 0, min(self.window, total_tasks))
        while t < total_tasks:
            k = min(self.window, total_tasks - t)
            cur = nxt
            if t + k < total_tasks:
                # double buffering: dispatch window t+1's schedule (conflict
                # matrix + levels) before blocking on window t's execution
                nxt = self._schedule(
                    base_key, t + k, min(self.window, total_tasks - t - k))
            state, n_waves = self._execute(state, cur)
            wave_counts.append(n_waves)
            n_windows += 1
            t += k
        total_waves = int(sum(int(w) for w in wave_counts))  # host sync here
        state = self._finalize_state(state)
        stats = {
            "total_tasks": total_tasks,
            "n_windows": n_windows,
            "total_waves": total_waves,
            "mean_parallelism": total_tasks / max(total_waves, 1),
        }
        return state, self._extend_stats(stats)

    def _extend_stats(self, stats: dict) -> dict:
        return stats
