"""Sequential oracle engine: the chain order, one task at a time.

This is the correctness reference every other engine is property-tested
against (bit-exact under the strict hazard rule). ``run_sequential`` is
the bare-function form kept for the existing call sites.
"""
from __future__ import annotations

from typing import Any

import jax

from repro.engine.base import Engine, register_engine
from repro.obs.stats import finalize_stats
from repro.obs.trace import current_tracer


def run_sequential(model, state, total_tasks: int, *, seed: int = 0,
                   window: int = 256):
    """Oracle runner: same task stream, strictly sequential execution."""
    tr = current_tracer()
    base_key = jax.random.key(seed)
    t = 0
    index = 0
    seq = jax.jit(
        lambda st, key, start, count: model.execute_sequential(
            st, model.create_tasks(key, start, window), count
        )
    )
    while t < total_tasks:
        k = min(window, total_tasks - t)
        if tr is None:
            state = seq(state, base_key, t, k)
        else:
            with tr.span("execute", index=index, start=t, count=k,
                         sequential=True):
                state = seq(state, base_key, t, k)
                jax.block_until_ready(state)
        t += k
        index += 1
    return state


@register_engine
class SequentialEngine(Engine):
    """Registry wrapper around ``run_sequential`` (stats are trivial:
    every task is its own wave)."""

    name = "sequential"

    def run(self, state: Any, total_tasks: int, *, seed: int = 0):
        from contextlib import nullcontext

        tr = current_tracer()
        run_cm = (tr.span("run", engine=self.name, window=self.window,
                          total_tasks=total_tasks, overlap=False)
                  if tr is not None else nullcontext())
        with run_cm:
            state = run_sequential(self.model, state, total_tasks,
                                   seed=seed, window=self.window)
        stats = {
            "total_tasks": total_tasks,
            "n_windows": -(-total_tasks // self.window) if total_tasks else 0,
            "total_waves": total_tasks,
            "mean_parallelism": 1.0,
        }
        return state, finalize_stats(stats)
