"""Pluggable execution engines for the wavefront protocol.

  base.py       — ``Engine`` interface, registry, shared windowed loop
                  (barrier and cross-window-overlapped variants)
  sequential.py — chain-order oracle (``sequential``)
  wavefront.py  — single-device vectorized waves (``wavefront``;
                  ``wavefront_overlap`` fuses window k+1's head waves
                  into window k's tail drain)
  sharded.py    — shard_map over the agent axis: halo-exchange comm
                  (``sharded``) with the full-state all_gather layout as
                  explicit fallback (``sharded_replicated``) and the
                  pair-halo overlapped mode (``sharded_overlap``)

All engines run the identical task stream and are bit-exact under the
strict hazard rule; pick by name through ``make_engine`` (or
``ProtocolConfig.engine`` at the ``repro.core`` API level). The
``overlap`` kwarg flips any windowed engine between the conservative
window barrier and cross-window overlapped execution; the ``*_overlap``
registry names are the overlapped defaults the differential harness and
benchmarks sweep.
"""
from repro.engine.base import (
    ENGINES,
    Engine,
    WindowedEngine,
    get_engine,
    make_engine,
    register_engine,
)
from repro.engine.sequential import SequentialEngine, run_sequential
from repro.engine.sharded import (
    ShardedEngine,
    ShardedOverlapEngine,
    ShardedReplicatedEngine,
)
from repro.engine.wavefront import (
    WavefrontEngine,
    WavefrontOverlapEngine,
    WavefrontRunner,
)

__all__ = [
    "ENGINES",
    "Engine",
    "WindowedEngine",
    "get_engine",
    "make_engine",
    "register_engine",
    "SequentialEngine",
    "run_sequential",
    "ShardedEngine",
    "ShardedOverlapEngine",
    "ShardedReplicatedEngine",
    "WavefrontEngine",
    "WavefrontOverlapEngine",
    "WavefrontRunner",
]
