"""Pluggable execution engines for the wavefront protocol.

  base.py       — ``Engine`` interface, registry, shared windowed loop
  sequential.py — chain-order oracle (``sequential``)
  wavefront.py  — single-device vectorized waves (``wavefront``)
  sharded.py    — shard_map over the agent axis: halo-exchange comm
                  (``sharded``) with the full-state all_gather layout as
                  explicit fallback (``sharded_replicated``)

All engines run the identical task stream and are bit-exact under the
strict hazard rule; pick by name through ``make_engine`` (or
``ProtocolConfig.engine`` at the ``repro.core`` API level).
"""
from repro.engine.base import (
    ENGINES,
    Engine,
    WindowedEngine,
    get_engine,
    make_engine,
    register_engine,
)
from repro.engine.sequential import SequentialEngine, run_sequential
from repro.engine.sharded import ShardedEngine, ShardedReplicatedEngine
from repro.engine.wavefront import WavefrontEngine, WavefrontRunner

__all__ = [
    "ENGINES",
    "Engine",
    "WindowedEngine",
    "get_engine",
    "make_engine",
    "register_engine",
    "SequentialEngine",
    "run_sequential",
    "ShardedEngine",
    "ShardedReplicatedEngine",
    "WavefrontEngine",
    "WavefrontRunner",
]
