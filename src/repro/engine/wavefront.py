"""Single-device wavefront engine (formerly ``core.wavefront
.WavefrontRunner``, now behind the engine registry).

Streams the chain through windows of W tasks: each window is scheduled
(prefix-conflict matrix through the conflict kernel, wave levels through
the levels kernel — backend auto-detected) and executed one vectorized
wave at a time. By default the window boundary is a conservative
barrier, so cross-window ordering is trivially preserved; the shared
``WindowedEngine`` loop overlaps window t+1's scheduling with window t's
execution.

With ``overlap=True`` (or the ``wavefront_overlap`` registry entry) the
barrier falls: window k+1 is re-leveled against the carry-over conflict
frontier of window k's tail (``WindowedEngine`` docstring) and the two
windows drain in *fused* waves — each wave executes window k's tasks at
that level and then window k+1's, which never conflict with them by
construction of the frontier. Bit-exactness vs the sequential oracle is
unchanged (differential-harness-tested); what changes is the wave count:
independent head waves of k+1 ride along with k's tail instead of
waiting behind it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.engine.base import WindowedEngine, register_engine


@register_engine
class WavefrontEngine(WindowedEngine):
    name = "wavefront"

    def __init__(self, model, *, window: int = 256, strict: bool = True,
                 jit: bool = True, overlap: bool | None = None):
        super().__init__(model, window=window, strict=strict,
                         overlap=overlap)
        self._jit = jit
        # deferred so `import repro.engine` works before repro.core's
        # package init has run (core's init imports this module for the
        # WavefrontRunner compat re-export)
        from repro.core.wavefront import execute_window

        def _execute(state, sched):
            recipes, valid, levels = sched
            return execute_window(model, state, recipes, valid,
                                  strict=self.strict, levels=levels)

        # NB: no donation here — callers hand this engine externally owned
        # state (and often reuse it for the oracle run); the sharded engine
        # donates because it owns its device_put copy.
        self._schedule = (jax.jit(self._schedule_window) if jit
                          else self._schedule_window)
        self._execute = jax.jit(_execute) if jit else _execute

        def _schedule_ov(base_key, start, count):
            recipes, valid, conf = self._schedule_window_ov(
                base_key, start, count)
            return recipes, valid, conf, None

        def _execute_pair(state, cur, lv_a, nxt, lv_b):
            rec_a, rec_b = cur[0], nxt[0]
            n_waves = jnp.max(lv_a) + 1

            def body(carry):
                w, st = carry
                # fused wave: window k's tasks at level w, then window
                # k+1's — the carry frontier guarantees the two masks
                # never hold conflicting tasks, so order is immaterial
                st = model.execute_wave(st, rec_a, lv_a == w)
                st = model.execute_wave(st, rec_b, lv_b == w)
                return w + 1, st

            from repro.obs.profiler import annotate

            with annotate("protocol.execute_pair"):
                _, state = jax.lax.while_loop(
                    lambda c: c[0] < n_waves, body, (jnp.int32(0), state))
            # rebase the next window onto the new level clock; executed
            # (and invalid) tasks drop to -1
            lv_b = jnp.where(lv_b >= n_waves, lv_b - n_waves, -1)
            return state, n_waves, lv_b

        self._schedule_ov = jax.jit(_schedule_ov) if jit else _schedule_ov
        self._execute_pair = (jax.jit(_execute_pair) if jit
                              else _execute_pair)
        # partnerless drain (last / only window): the barrier executor
        # already takes (recipes, valid, levels) — reuse it so no
        # empty-mask partner waves are executed
        self._execute_drain = lambda state, cur, lv: self._execute(
            state, (cur[0], cur[1], lv))

    def _trace_parts(self, sched, levels=None):
        # barrier schedule carries its levels in slot 2; the overlapped
        # loop re-levels and passes them explicitly. Single device: no
        # write-owner or halo-row attributes.
        lv = sched[2] if levels is None else levels
        return lv, None, None

    def _cost_targets(self, base_key, state):
        if not self._jit:
            return None
        sched = self._schedule(base_key, 0, self.window)
        return [("execute_window", self._execute, (state, sched))]


@register_engine
class WavefrontOverlapEngine(WavefrontEngine):
    """``wavefront`` with cross-window overlap on by default — the
    registry entry the differential harness and benchmarks sweep; the
    plain ``wavefront`` engine stays the registered barrier fallback."""

    name = "wavefront_overlap"
    default_overlap = True


#: Backwards-compatible name for the pre-registry runner class.
WavefrontRunner = WavefrontEngine
