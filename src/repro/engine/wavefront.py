"""Single-device wavefront engine (formerly ``core.wavefront
.WavefrontRunner``, now behind the engine registry).

Streams the chain through windows of W tasks: each window is scheduled
(prefix-conflict matrix through the conflict kernel, wave levels through
the levels kernel — backend auto-detected) and executed one vectorized
wave at a time. The window boundary is a conservative barrier, so
cross-window ordering is trivially preserved; the shared
``WindowedEngine`` loop overlaps window t+1's scheduling with window t's
execution.
"""
from __future__ import annotations

import jax

from repro.engine.base import WindowedEngine, register_engine


@register_engine
class WavefrontEngine(WindowedEngine):
    name = "wavefront"

    def __init__(self, model, *, window: int = 256, strict: bool = True,
                 jit: bool = True):
        super().__init__(model, window=window, strict=strict)
        # deferred so `import repro.engine` works before repro.core's
        # package init has run (core's init imports this module for the
        # WavefrontRunner compat re-export)
        from repro.core.wavefront import execute_window

        def _execute(state, sched):
            recipes, valid, levels = sched
            return execute_window(model, state, recipes, valid,
                                  strict=self.strict, levels=levels)

        # NB: no donation here — callers hand this engine externally owned
        # state (and often reuse it for the oracle run); the sharded engine
        # donates because it owns its device_put copy.
        self._schedule = (jax.jit(self._schedule_window) if jit
                          else self._schedule_window)
        self._execute = jax.jit(_execute) if jit else _execute


#: Backwards-compatible name for the pre-registry runner class.
WavefrontRunner = WavefrontEngine
