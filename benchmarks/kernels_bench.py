"""Kernel micro-benchmarks (interpret-mode wall time is NOT TPU time — the
useful numbers are the pure-jnp oracle timings, which XLA compiles for CPU;
reported for completeness and trend tracking)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.utils.timing import median_time

rng = np.random.RandomState(0)


def bench_conflict():
    from repro.kernels.conflict.ref import conflict_matrix_ref

    w = 512
    reads = jnp.asarray(rng.randint(0, 10_000, (w, 2)), jnp.int32)
    writes = reads[:, 1:]
    valid = jnp.ones((w,), bool)
    f = jax.jit(lambda r, wr, v: conflict_matrix_ref(r, wr, v, strict=True))
    t = median_time(lambda: f(reads, writes, valid))
    return [("conflict_ref_512", t * 1e6, f"{w*w/t/1e6:.0f} Mpairs/s")]


def bench_axelrod_wave():
    from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
    from repro.core.wavefront import WavefrontRunner

    rows = []
    for f_ in (3, 150, 500):
        m = AxelrodModel(AxelrodConfig(n_agents=10_000, n_features=f_))
        st = m.init_state(jax.random.key(0))
        runner = WavefrontRunner(m, window=256)
        t = median_time(lambda: runner._step(st, jax.random.key(1), 0),
                        repeats=3)
        rows.append((f"axelrod_window256_F{f_}", t * 1e6,
                     f"{256/t:.0f} tasks/s"))
    return rows


def bench_sir_wave():
    from repro.mabs.sir import SIRConfig, SIRModel
    from repro.core.wavefront import WavefrontRunner

    rows = []
    for s in (10, 100, 1000):
        m = SIRModel(SIRConfig(n_agents=4_000, k=14, subset_size=s))
        st = m.init_state(jax.random.key(0))
        w = min(64, 2 * m.cfg.n_subsets)
        runner = WavefrontRunner(m, window=w)
        t = median_time(lambda: runner._step(st, jax.random.key(1), 0),
                        repeats=3)
        rows.append((f"sir_window{w}_s{s}", t * 1e6,
                     f"{w*s/t:.0f} agent-updates/s"))
    return rows


def bench_wkv6():
    from repro.models.rwkv6 import wkv6_chunked_jnp

    b, h, t, d = 2, 8, 512, 64
    f = lambda *sh: jnp.asarray(rng.randn(*sh).astype(np.float32) * 0.3)
    r, k, v = f(b, h, t, d), f(b, h, t, d), f(b, h, t, d)
    w = jnp.exp(-jnp.exp(f(b, h, t, d)))
    u = f(h, d)
    fn = jax.jit(lambda *a: wkv6_chunked_jnp(*a, chunk=64)[0])
    tt = median_time(lambda: fn(r, k, v, w, u), repeats=3)
    return [("wkv6_chunked_jnp_2x8x512x64", tt * 1e6,
             f"{b*t/tt:.0f} tok/s")]


def bench_attention():
    from repro.models.attention import attention_inner

    b, h, hkv, t, d = 1, 8, 2, 1024, 64
    f = lambda *sh: jnp.asarray(rng.randn(*sh).astype(np.float32) * 0.3)
    q, k, v = f(b, h, t, d), f(b, hkv, t, d), f(b, hkv, t, d)
    fn = jax.jit(lambda q, k, v: attention_inner(q, k, v, impl="chunked",
                                                 chunk=256))
    tt = median_time(lambda: fn(q, k, v), repeats=3)
    return [("attn_chunked_1x8x1024x64", tt * 1e6, f"{b*t/tt:.0f} tok/s")]


def run_all():
    rows = []
    for fn in (bench_conflict, bench_axelrod_wave, bench_sir_wave,
               bench_wkv6, bench_attention):
        rows.extend(fn())
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    return rows


if __name__ == "__main__":
    run_all()
