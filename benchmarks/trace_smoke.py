"""Trace-export smoke: the observability acceptance path in one script.

Runs a small sharded-overlap scenario with the span tracer installed,
asserts the traced run stays bit-exact vs the sequential oracle, exports
the Chrome trace-event JSON, schema-validates it, and checks the span
taxonomy the docs promise (window schedule/execute/boundary spans, wave
spans, halo_gather spans with rows/bytes/rung attributes). CI runs it
under 8 virtual host devices and uploads the exported trace as an
artifact; load it in ui.perfetto.dev to browse the schedule.

Run:  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python benchmarks/trace_smoke.py [--out TRACE.json]
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "TRACE_smoke.json"))
    ap.add_argument("--engine", default="sharded_overlap")
    ap.add_argument("--total", type=int, default=100)
    ap.add_argument("--window", type=int, default=32)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.core import ProtocolConfig, run_oracle
    from repro.engine import make_engine
    from repro.mabs.voter import VoterModel
    from repro.obs import tracing, validate_chrome_trace
    from repro.topology import watts_strogatz

    model = VoterModel(watts_strogatz(64, 4, 0.2, jax.random.key(5)))
    st0 = model.init_state(jax.random.key(1))
    cfg = ProtocolConfig(window=args.window, strict=True)
    oracle = run_oracle(model, st0, args.total, seed=2, config=cfg)

    eng = make_engine(args.engine, model, window=args.window, strict=True)
    with tracing() as tr:
        out, stats = eng.run(st0, args.total, seed=2)

    # tracing must not perturb the protocol: bit-exact vs the oracle
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(oracle)):
        assert bool(jnp.all(a == b)), "traced run diverged from the oracle"

    path = os.path.abspath(args.out)
    payload = tr.export(path)
    n_events = validate_chrome_trace(payload)
    events = payload["traceEvents"]
    names = {e["name"] for e in events}
    want = {"run", "schedule", "execute", "wave"}
    if args.engine.endswith("_overlap"):
        want.add("boundary")
    if args.engine.startswith("sharded"):
        want.add("halo_gather")
    missing = want - names
    assert not missing, f"trace is missing span kinds: {sorted(missing)}"
    for e in events:
        if e["name"] == "halo_gather":
            for k in ("rung", "rows", "bytes"):
                assert k in e["args"], f"halo_gather span missing {k!r}"
    print(f"TRACE-OK {path} ({n_events} events, "
          f"{jax.device_count()} devices, engine={args.engine}, "
          f"waves={stats['total_waves']})")


if __name__ == "__main__":
    main()
