"""Roofline builders: the LM dry-run table (historic) and the MABS
engine roofline + T(W, n) cost-model fit (the protocol half).

MABS section (``mabs_roofline_rows`` / ``fit_tn_cost_model``, rendered
by ``report.py explain BENCH_engine.json``):

  * Per engine row carrying compiled-cost telemetry (the ``cost`` field
    ``engine_sweep`` captures via ``Engine.compiled_costs``), three
    bound terms in seconds — compute, memory, collective. XLA's
    cost_analysis counts ``while`` bodies ONCE, and the engines' wave
    loops have data-dependent trips, so the per-call FLOPs/bytes are
    multiplied by the *executed* wave count; the collective term uses
    the HLO-parsed per-device receive bytes already resolved against the
    runtime comm ledger (``collective_bytes`` — exact by the cross-check
    identity). ``max(terms)`` is the roofline bound; measured/bound says
    how far the engine sits above it.
  * The fig3-style T(W, n) cost model is fitted against the
    ``kind:"tn"`` rows: per model, least squares over
    T ≈ c_sched·n_windows·W² + c_wave·waves + c_task·tasks + c0 —
    the schedule's O(W²) record check, the per-wave dispatch overhead,
    the per-task execute work, and a constant. Per-family residuals
    validate it (closing the ROADMAP item's open fitting half).

LM section (below) — unchanged dry-run roofline.

Three terms per (arch × shape × mesh), in seconds (v5e constants):

  compute    = FLOPs_analytic            / (chips · 197e12 FLOP/s)
  memory     = bytes_analytic            / (chips · 819e9 B/s)
  collective = wire_bytes_per_device     / (50e9 B/s per ICI link)

FLOPs/bytes use analytic per-architecture formulas (documented below and
cross-checked against compiled cost_analysis): XLA's cost analysis counts
`while` bodies ONCE (verified on this toolchain), so raw numbers
undercount scanned layers by ~n_layers×; the HLO-parsed collective bytes
ARE loop-corrected via recovered trip counts (launch/hlo_analysis.py).
Both raw and corrected values are kept in the artifacts for audit.

MODEL_FLOPS (the "useful" floor) = 6·N·tokens (dense) / 6·N_active·tokens
(MoE); the compute term additionally carries the quadratic attention term
where applicable — their ratio exposes remat/attention overhead.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # per chip
LINK_BW = 50e9             # per ICI link

ARTIFACT_DIR = "artifacts/dryrun"


def _arch_cfg(name):
    from repro.configs import get_config

    return get_config(name)


def analytic_costs(rec: dict) -> dict:
    """Analytic FLOPs and HBM bytes for the whole step (all chips)."""
    cfg = _arch_cfg(rec["arch"])
    b, t = rec["global_batch"], rec["seq_len"]
    n_active = cfg.n_active_params()
    n_total = cfg.n_params()
    L, hq, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    win = cfg.sliding_window

    if rec["kind"] == "train":
        tokens = b * t
        model_flops = 6 * n_active * tokens
        # attention logits+value matmuls, fwd+bwd (x3 of fwd 4·T·Teff·H·hd)
        teff = t / 2 if win is None else min(win, t)
        attn = 12 * L * hq * hd * t * teff * b
        if cfg.is_encdec:
            attn *= 2  # encoder + cross attention, coarse
        if cfg.family == "ssm":
            attn = 0
        flops = model_flops + attn
        # bytes: params read fwd+bwd + grads w + opt (m,v rw, p rw f32) +
        # activations (residual stream rw per layer, bf16)
        pbytes = n_total * 2
        opt_bytes = n_total * 4 * 6
        act = L * tokens * cfg.d_model * 2 * 4
        bytes_ = 3 * pbytes + opt_bytes + act
    elif rec["kind"] == "prefill":
        tokens = b * t
        model_flops = 2 * n_active * tokens
        teff = t / 2 if win is None else min(win, t)
        attn = 4 * L * hq * hd * t * teff * b
        if cfg.family == "ssm":
            attn = 0
        flops = model_flops + attn
        bytes_ = n_total * 2 + L * tokens * cfg.d_model * 2 * 2
    else:  # decode: one token against a cache of length t
        model_flops = 2 * n_active * b
        s_eff = t if win is None else min(win, t)
        attn = 4 * L * hq * hd * s_eff * b
        cache_bytes = (2 * L * cfg.n_kv_heads * hd * s_eff * b * 2)
        if cfg.family == "ssm":
            attn = 0
            cache_bytes = L * (cfg.d_model // hd) * hd * hd * 4 * b
        if cfg.family == "hybrid":
            # 3 global layers full cache, rest windowed + SSM state
            glob_l = len(cfg.global_layers)
            cache_bytes = 2 * b * cfg.n_kv_heads * hd * 2 * (
                glob_l * t + (L - glob_l) * min(win or t, t))
            nh = cfg.ssm.n_heads or cfg.d_model // cfg.ssm.head_dim
            cache_bytes += L * b * nh * cfg.ssm.head_dim * \
                cfg.ssm.state_dim * 4
        flops = model_flops + attn
        # params + cache read once per decode step
        bytes_ = n_total * 2 + cache_bytes
        model_flops = model_flops  # per-token useful work
    return {
        "flops_analytic": float(flops),
        "bytes_analytic": float(bytes_),
        "model_flops": float(6 * n_active * b * t if rec["kind"] == "train"
                             else (2 * n_active * b * t
                                   if rec["kind"] == "prefill"
                                   else 2 * n_active * b)),
    }


def roofline_row(rec: dict) -> dict:
    chips = 512 if rec["mesh"] == "multi" else 256
    an = analytic_costs(rec)
    t_compute = an["flops_analytic"] / (chips * PEAK_FLOPS)
    t_memory = an["bytes_analytic"] / (chips * HBM_BW)
    wire = rec["collectives"]["total_wire_bytes"]  # per-device already
    t_coll = wire / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = an["model_flops"] / (chips * PEAK_FLOPS)
    row = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": an["model_flops"],
        "flops_analytic": an["flops_analytic"],
        "hlo_flops_raw": rec["cost_analysis"]["flops"],
        "max_loop_multiplier": rec.get("max_loop_multiplier", 1),
        "roofline_fraction": useful / bound if bound > 0 else 0.0,
        "useful_vs_analytic": (an["model_flops"] / an["flops_analytic"]
                               if an["flops_analytic"] else 0.0),
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
    }
    return row


def build_table(artifact_dir: str = ARTIFACT_DIR, mesh: str | None = None,
                opt: bool = False):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if bool(rec.get("opt")) != opt:
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                         "mesh": rec.get("mesh"),
                         "status": rec.get("status"),
                         "skip_reason": rec.get("skip_reason", "")})
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(roofline_row(rec) | {"status": "ok"})
    return rows


# --------------------------------------------------------------------------
# MABS engine roofline + T(W, n) cost-model fit (BENCH_engine.json rows)

#: roofline peaks per backend. TPU: the v5e constants above. CPU: order-
#: of-magnitude host figures (a few-core AVX box; virtual-device "links"
#: are memcpys through the same memory system) — the CPU roofline ranks
#: bound terms and engines against each other, it is not a calibrated
#: absolute bound.
MABS_PEAKS = {
    "tpu": {"flops": PEAK_FLOPS, "mem_bw": HBM_BW, "link_bw": LINK_BW},
    "cpu": {"flops": 5e10, "mem_bw": 2e10, "link_bw": 1e10},
}


def mabs_roofline_rows(bench: dict) -> list[dict]:
    """Roofline terms for every engine row carrying compiled-cost
    telemetry (the ``cost`` field captured by engine_sweep). Per-call
    cost_analysis FLOPs/bytes count the wave loop's body once, so both
    scale by the executed wave count; the collective term is the
    ledger-cross-checked HLO receive-byte total for the whole run."""
    peaks = MABS_PEAKS.get(bench.get("meta", {}).get("backend", "cpu"),
                           MABS_PEAKS["cpu"])
    out = []
    for r in bench.get("rows", []):
        c = r.get("cost")
        if not c or r.get("kind") != "engine":
            continue
        waves = max(int(r["total_waves"]), 1)
        t_comp = c["flops"] * waves / peaks["flops"]
        t_mem = c["bytes_accessed"] * waves / peaks["mem_bw"]
        coll = c.get("collective_bytes") or 0
        t_coll = coll / peaks["link_bw"]
        terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
        bound = max(terms.values())
        measured = float(r["seconds"])
        out.append({
            "model": r["model"], "engine": r["engine"],
            "window": r["window"], "n_devices": r["n_devices"],
            "n_agents": r["n_agents"],
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_collective_s": t_coll,
            "dominant": max(terms, key=terms.get),
            "bound_s": bound, "measured_s": measured,
            "above_bound": measured / bound if bound > 0 else float("inf"),
            "executor": c.get("executor"),
            "peak_bytes": c.get("peak_bytes"),
            "coll_ledger_ratio": r.get("coll_ledger_ratio"),
        })
    return out


#: T(W, n) fit features, in coefficient order (see fit_tn_cost_model)
TN_FEATURES = ("c_sched[s/W^2]", "c_wave[s/wave]", "c_agent[s/(wave*n)]",
               "c0[s]")


def fit_tn_cost_model(tn_rows: list[dict]) -> list[dict]:
    """Least-squares fit of the fig3-style T(W, n) cost model against
    the ``kind:"tn"`` sweep rows, one fit per model:

        T(run) ≈ c_sched · n_windows·W²  (the O(W²) record check)
               + c_wave  · waves         (per-wave dispatch overhead)
               + c_agent · waves·n       (per-wave full-state traffic)
               + c0                      (constant dispatch floor)

    Returns per-model coefficient dicts with overall relative-RMS /
    R² and per-topology-family residuals — the validation half of the
    ROADMAP's cost-model item; the future cost-aware scheduler picks W
    from these coefficients."""
    import numpy as np

    fits = []
    for model in sorted({r["model"] for r in tn_rows}):
        rows = [r for r in tn_rows if r["model"] == model]
        if len(rows) < len(TN_FEATURES):
            continue
        feats, y = [], []
        for r in rows:
            n_windows = max(int(r["total_tasks"]) // int(r["window"]), 1)
            feats.append([n_windows * float(r["window"]) ** 2,
                          float(r["total_waves"]),
                          float(r["total_waves"]) * float(r["n_agents"]),
                          1.0])
            y.append(float(r["seconds"]))
        X = np.asarray(feats)
        y = np.asarray(y)
        # column scaling for conditioning (W² vs waves·n span ~6 decades)
        scale = X.max(axis=0)
        scale[scale == 0] = 1.0
        coef_s, *_ = np.linalg.lstsq(X / scale, y, rcond=None)
        coef = coef_s / scale
        pred = X @ coef
        resid = y - pred
        ss_res = float((resid ** 2).sum())
        ss_tot = float(((y - y.mean()) ** 2).sum()) or 1.0
        by_family: dict = {}
        for r, p, yy in zip(rows, pred, y):
            fam = by_family.setdefault(r["topology"], [])
            fam.append((yy - p) / yy if yy else 0.0)
        fits.append({
            "model": model,
            "n_rows": len(rows),
            "coef": dict(zip(TN_FEATURES, (float(c) for c in coef))),
            "r2": 1.0 - ss_res / ss_tot,
            "rms_rel": float(np.sqrt(np.mean((resid / y) ** 2))),
            "residuals_by_family": {
                fam: {"rms_rel": float(np.sqrt(np.mean(np.square(v)))),
                      "n": len(v)}
                for fam, v in sorted(by_family.items())},
        })
    return fits


def main():
    rows = build_table()
    print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "roofline_fraction,useful_vs_analytic,temp_gib")
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r.get('arch')},{r.get('shape')},{r.get('mesh')},"
                  f"SKIP/{r.get('status')},{r.get('skip_reason', '')[:40]}")
            continue
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
              f"{r['t_collective_s']:.4g},{r['dominant']},"
              f"{r['roofline_fraction']:.3f},{r['useful_vs_analytic']:.3f},"
              f"{r['temp_gib']:.1f}")


if __name__ == "__main__":
    main()
