"""Roofline builder — turns dry-run artifacts into the §Roofline table.

Three terms per (arch × shape × mesh), in seconds (v5e constants):

  compute    = FLOPs_analytic            / (chips · 197e12 FLOP/s)
  memory     = bytes_analytic            / (chips · 819e9 B/s)
  collective = wire_bytes_per_device     / (50e9 B/s per ICI link)

FLOPs/bytes use analytic per-architecture formulas (documented below and
cross-checked against compiled cost_analysis): XLA's cost analysis counts
`while` bodies ONCE (verified on this toolchain), so raw numbers
undercount scanned layers by ~n_layers×; the HLO-parsed collective bytes
ARE loop-corrected via recovered trip counts (launch/hlo_analysis.py).
Both raw and corrected values are kept in the artifacts for audit.

MODEL_FLOPS (the "useful" floor) = 6·N·tokens (dense) / 6·N_active·tokens
(MoE); the compute term additionally carries the quadratic attention term
where applicable — their ratio exposes remat/attention overhead.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # per chip
LINK_BW = 50e9             # per ICI link

ARTIFACT_DIR = "artifacts/dryrun"


def _arch_cfg(name):
    from repro.configs import get_config

    return get_config(name)


def analytic_costs(rec: dict) -> dict:
    """Analytic FLOPs and HBM bytes for the whole step (all chips)."""
    cfg = _arch_cfg(rec["arch"])
    b, t = rec["global_batch"], rec["seq_len"]
    n_active = cfg.n_active_params()
    n_total = cfg.n_params()
    L, hq, hd = cfg.n_layers, cfg.n_heads, cfg.hd
    win = cfg.sliding_window

    if rec["kind"] == "train":
        tokens = b * t
        model_flops = 6 * n_active * tokens
        # attention logits+value matmuls, fwd+bwd (x3 of fwd 4·T·Teff·H·hd)
        teff = t / 2 if win is None else min(win, t)
        attn = 12 * L * hq * hd * t * teff * b
        if cfg.is_encdec:
            attn *= 2  # encoder + cross attention, coarse
        if cfg.family == "ssm":
            attn = 0
        flops = model_flops + attn
        # bytes: params read fwd+bwd + grads w + opt (m,v rw, p rw f32) +
        # activations (residual stream rw per layer, bf16)
        pbytes = n_total * 2
        opt_bytes = n_total * 4 * 6
        act = L * tokens * cfg.d_model * 2 * 4
        bytes_ = 3 * pbytes + opt_bytes + act
    elif rec["kind"] == "prefill":
        tokens = b * t
        model_flops = 2 * n_active * tokens
        teff = t / 2 if win is None else min(win, t)
        attn = 4 * L * hq * hd * t * teff * b
        if cfg.family == "ssm":
            attn = 0
        flops = model_flops + attn
        bytes_ = n_total * 2 + L * tokens * cfg.d_model * 2 * 2
    else:  # decode: one token against a cache of length t
        model_flops = 2 * n_active * b
        s_eff = t if win is None else min(win, t)
        attn = 4 * L * hq * hd * s_eff * b
        cache_bytes = (2 * L * cfg.n_kv_heads * hd * s_eff * b * 2)
        if cfg.family == "ssm":
            attn = 0
            cache_bytes = L * (cfg.d_model // hd) * hd * hd * 4 * b
        if cfg.family == "hybrid":
            # 3 global layers full cache, rest windowed + SSM state
            glob_l = len(cfg.global_layers)
            cache_bytes = 2 * b * cfg.n_kv_heads * hd * 2 * (
                glob_l * t + (L - glob_l) * min(win or t, t))
            nh = cfg.ssm.n_heads or cfg.d_model // cfg.ssm.head_dim
            cache_bytes += L * b * nh * cfg.ssm.head_dim * \
                cfg.ssm.state_dim * 4
        flops = model_flops + attn
        # params + cache read once per decode step
        bytes_ = n_total * 2 + cache_bytes
        model_flops = model_flops  # per-token useful work
    return {
        "flops_analytic": float(flops),
        "bytes_analytic": float(bytes_),
        "model_flops": float(6 * n_active * b * t if rec["kind"] == "train"
                             else (2 * n_active * b * t
                                   if rec["kind"] == "prefill"
                                   else 2 * n_active * b)),
    }


def roofline_row(rec: dict) -> dict:
    chips = 512 if rec["mesh"] == "multi" else 256
    an = analytic_costs(rec)
    t_compute = an["flops_analytic"] / (chips * PEAK_FLOPS)
    t_memory = an["bytes_analytic"] / (chips * HBM_BW)
    wire = rec["collectives"]["total_wire_bytes"]  # per-device already
    t_coll = wire / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    useful = an["model_flops"] / (chips * PEAK_FLOPS)
    row = {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": an["model_flops"],
        "flops_analytic": an["flops_analytic"],
        "hlo_flops_raw": rec["cost_analysis"]["flops"],
        "max_loop_multiplier": rec.get("max_loop_multiplier", 1),
        "roofline_fraction": useful / bound if bound > 0 else 0.0,
        "useful_vs_analytic": (an["model_flops"] / an["flops_analytic"]
                               if an["flops_analytic"] else 0.0),
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "args_gib": rec["memory"]["argument_bytes"] / 2**30,
    }
    return row


def build_table(artifact_dir: str = ARTIFACT_DIR, mesh: str | None = None,
                opt: bool = False):
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if bool(rec.get("opt")) != opt:
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec.get("arch"), "shape": rec.get("shape"),
                         "mesh": rec.get("mesh"),
                         "status": rec.get("status"),
                         "skip_reason": rec.get("skip_reason", "")})
            continue
        if mesh and rec["mesh"] != mesh:
            continue
        rows.append(roofline_row(rec) | {"status": "ok"})
    return rows


def main():
    rows = build_table()
    print("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,dominant,"
          "roofline_fraction,useful_vs_analytic,temp_gib")
    for r in rows:
        if r.get("status") != "ok":
            print(f"{r.get('arch')},{r.get('shape')},{r.get('mesh')},"
                  f"SKIP/{r.get('status')},{r.get('skip_reason', '')[:40]}")
            continue
        print(f"{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
              f"{r['t_collective_s']:.4g},{r['dominant']},"
              f"{r['roofline_fraction']:.3f},{r['useful_vs_analytic']:.3f},"
              f"{r['temp_gib']:.1f}")


if __name__ == "__main__":
    main()
