"""Append-only benchmark history ledger.

``BENCH_engine.json`` is overwritten in place by every sweep, so the
perf *trajectory* of the repo was untracked — a regression that lands
together with a re-benchmark simply replaces the evidence. The ledger
fixes that: every sweep appends one immutable run record under
``benchmarks/ledger/``, named from the provenance header (UTC timestamp
+ git sha + backend), holding the same ``{"meta", "rows"}`` payload as
the BENCH artifact. Records are never rewritten: ``append_record``
refuses to overwrite, and ``report.py compare OLD NEW`` accepts any two
records (or BENCH files — same schema) to produce thresholded per-row
verdicts. CI's ``bench-regression`` job appends a record per run and
gates on the comparison against the committed baseline.
"""
from __future__ import annotations

import json
import os
import re

LEDGER_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "ledger")


def record_name(meta: dict) -> str:
    """Deterministic record filename from the provenance header:
    ``<utc-timestamp>__<git-sha>__<backend>.json`` (filesystem-safe)."""
    prov = (meta or {}).get("provenance") or {}
    ts = re.sub(r"[^0-9TZ]", "", str(prov.get("timestamp", "unknown")))
    sha = prov.get("git_sha") or "nogit"
    backend = prov.get("backend") or meta.get("backend") or "unknown"
    return f"{ts}__{sha}__{backend}.json"


def append_record(payload: dict, ledger_dir: str | None = None) -> str:
    """Append one run record; returns its path. Append-only by
    construction: an existing record is never overwritten."""
    d = ledger_dir or LEDGER_DIR
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, record_name(payload.get("meta", {})))
    if os.path.exists(path):
        raise FileExistsError(
            f"ledger record {path} already exists — records are "
            "append-only; re-run the sweep for a fresh provenance stamp")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
    os.replace(tmp, path)
    return path


def list_records(ledger_dir: str | None = None) -> list[str]:
    """Record paths in name (= timestamp) order, oldest first."""
    d = ledger_dir or LEDGER_DIR
    if not os.path.isdir(d):
        return []
    return [os.path.join(d, n) for n in sorted(os.listdir(d))
            if n.endswith(".json")]


def load_record(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
