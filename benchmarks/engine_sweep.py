"""Engine sweep: wavefront vs sharded throughput across device counts.

For each scenario in {voter, SIS, Axelrod} x window size x device count,
runs the same task stream through the ``wavefront`` (single-device),
``wavefront_overlap`` (cross-window overlapped waves), ``sharded``
(per-wave halo split over the agent axis), ``sharded_overlap`` (overlap
+ per-fused-wave slabs), ``sharded_window_halo`` (the monolithic
window/pair-halo middle rung) and ``sharded_replicated`` (full-state
all_gather) engines and reports end-to-end throughput (tasks/s,
scheduling + execution included), the schedule shape, for the sharded
engines the per-wave communication volume (rows / payload bytes
actually shipped per device per wave vs the monolithic window halo and
the full state — ``comm_reduction_vs_window_halo`` is the split's win),
and for the overlapped engines the carry-over columns (mean/max overlap
depth, early-task counts and the carry frontier), so BENCH_engine.json
captures the per-wave split win, the halo win and the barrier-removal
win alongside tasks/s.

A second row family (``kind: "tn"``) is the fig3-style T(W, n) cost-
model sweep (ROADMAP item): wavefront-engine seconds/task for voter and
SIS over the five topology families × agent counts × window sizes, the
MABS analog of the paper's T(s, n) subset-size sweep — it runs in the
single-device subprocess so its timings share the engine rows'
conditions.

Device counts are realized per subprocess via
``--xla_force_host_platform_device_count`` so one invocation sweeps
several mesh sizes on CPU; on a real TPU backend the script uses the
actual devices instead (forcing host-platform devices would hide them)
and sweeps prefixes of ``jax.devices()``.

Emits BENCH_engine.json next to the repo root (or --out PATH):

  {"meta": {...}, "rows": [{"kind": "engine", "model", "engine",
   "window", "n_devices", "n_agents", "total_tasks", "tasks_per_s",
   "total_waves", "mean_parallelism", "seconds", ...comm/overlap...},
   {"kind": "tn", "model", "topology", "n_agents", "window", ...}, ...]}

Run:  PYTHONPATH=src python benchmarks/engine_sweep.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

ENGINES = ("wavefront", "wavefront_overlap", "sharded", "sharded_overlap",
           "sharded_window_halo", "sharded_replicated")

#: T(W, n) sweep grid (fig3-style): families × agent counts × windows
TN_FAMILIES = ("ring", "lattice2d", "watts_strogatz", "erdos_renyi",
               "barabasi_albert")
TN_AGENTS = (1024, 4096, 16384)
TN_WINDOWS = (64, 256)


def _tn_topology(name: str, n: int, key):
    from repro.topology import (
        barabasi_albert,
        connect_isolated,
        erdos_renyi,
        lattice2d,
        ring,
        watts_strogatz,
    )

    import jax

    if name == "ring":
        return ring(n, 4)
    if name == "lattice2d":
        side = int(round(n ** 0.5))
        assert side * side == n, n
        return lattice2d(side, side, neighborhood="von_neumann")
    k1, k2 = jax.random.split(key)
    if name == "watts_strogatz":
        return connect_isolated(watts_strogatz(n, 4, 0.1, k1), k2)
    if name == "erdos_renyi":
        return connect_isolated(erdos_renyi(n, 4.0 / (n - 1), k1), k2)
    if name == "barabasi_albert":
        return barabasi_albert(n, 2, k1)
    raise ValueError(name)


def _tn_sweep(args) -> list[dict]:
    """fig3-style T(W, n): single-device wavefront seconds/task for
    voter and SIS over the topology families."""
    import jax

    from repro.engine import make_engine
    from repro.mabs.sis import SISModel
    from repro.mabs.voter import VoterModel
    from repro.utils.timing import median_time

    rows = []
    for fam in TN_FAMILIES:
        for n in TN_AGENTS:
            topo = _tn_topology(fam, n, jax.random.key(11))
            for mname, make in (("voter", VoterModel), ("sis", SISModel)):
                model = make(topo)
                state = model.init_state(jax.random.key(1))
                for window in TN_WINDOWS:
                    total = window * 2
                    eng = make_engine("wavefront", model, window=window)
                    _, stats = eng.run(state, total, seed=2)  # warmup
                    sec = median_time(
                        lambda: eng.run(state, total, seed=2)[0],
                        repeats=args.repeats, warmup=0)
                    rows.append({
                        "kind": "tn",
                        "model": mname,
                        "topology": fam,
                        "engine": "wavefront",
                        "n_agents": int(n),
                        "window": int(window),
                        "total_tasks": int(total),
                        "seconds": float(sec),
                        "seconds_min": float(sec.samples[0]),
                        "seconds_samples": list(sec.samples),
                        "tasks_per_s": float(total / sec),
                        "total_waves": int(stats["total_waves"]),
                        "mean_parallelism": float(stats["mean_parallelism"]),
                    })
                    print("ROW " + json.dumps(rows[-1]), flush=True)
    return rows


def _compiled_cost_fields(eng, state, stats) -> dict:
    """Compiled-cost telemetry for one engine row: AOT cost_analysis
    FLOPs/bytes + memory decomposition of the window executor the run
    dispatched, with the HLO-parsed collective bytes resolved against
    the runtime comm ledger's executed iteration counts. The hlo/ledger
    ratio (1.0 = exact) rides along as the in-artifact bug detector.
    Overlapped runs dispatch the pair executors and mix per-iteration
    widths across drain modes, so cost capture is barrier-mode only."""
    # read the last timed run's comm ledger BEFORE compiled_costs — its
    # _prepare_state call resets it (stats came from the warmup run, but
    # every run executes the same schedule, so the counts agree)
    iters = (eng.comm_iteration_counts(stats)
             if hasattr(eng, "comm_iteration_counts") else None)
    costs = eng.compiled_costs(state, seed=2)
    if not costs:
        return {}
    (_, cost), = costs.items()
    ledger_ratio = None
    ledger = stats.get("comm_bytes_total")
    # cross-check only on real meshes: a 1-device shard_map may elide
    # its collectives entirely, which is not a comm-accounting bug
    if iters is not None and ledger and getattr(eng, "n_devices", 1) > 1:
        ledger_ratio = cost.collectives.total_bytes(iters) / ledger
    return {"cost": cost.as_row(iters),
            "coll_ledger_ratio": ledger_ratio}


def _inner(args) -> None:
    """Runs inside one subprocess with a fixed device count. With
    ``--profile DIR`` the whole sweep runs under ``jax.profiler.trace``
    (one subdirectory per device count), so the device timeline carries
    the ``protocol.*`` named scopes of the kernels and halo gathers."""
    import jax

    from repro.obs.profiler import profile_session

    logdir = (os.path.join(args.profile, f"d{jax.device_count()}")
              if args.profile else None)
    with profile_session(logdir):
        _inner_body(args)


def _inner_body(args) -> None:
    import jax

    from repro.engine import make_engine
    from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
    from repro.mabs.sis import SISModel
    from repro.mabs.voter import VoterModel
    from repro.obs.stats import row_keys
    from repro.topology import watts_strogatz
    from repro.utils.timing import block_all, median_time

    if args.tn_only:
        _tn_sweep(args)
        return
    n = args.n
    topo = watts_strogatz(n, 4, 0.1, jax.random.key(0))
    models = {
        "voter": VoterModel(topo),
        "sis": SISModel(topo),
        "axelrod": AxelrodModel(AxelrodConfig(n_agents=n, n_features=3)),
    }
    rows = []
    for mname, model in models.items():
        state = model.init_state(jax.random.key(1))
        for window in args.windows:
            total = window * args.windows_per_run
            for ename in ENGINES:
                if ename.startswith("sharded") and jax.device_count() == 1 \
                        and args.skip_sharded_1dev:
                    continue
                eng = make_engine(ename, model, window=window)
                # warmup + stats; fence the warmup state so no queued
                # device work leaks into the first timed repeat
                out, stats = eng.run(state, total, seed=2)
                block_all(out)
                sec = median_time(lambda: eng.run(state, total, seed=2)[0],
                                  repeats=args.repeats, warmup=0)
                row = {
                    "kind": "engine",
                    "model": mname,
                    "engine": ename,
                    "window": int(window),
                    "n_devices": jax.device_count(),
                    "n_agents": int(n),
                    "total_tasks": int(total),
                    "tasks_per_s": float(total / sec),
                    "total_waves": int(stats["total_waves"]),
                    "mean_parallelism": float(stats["mean_parallelism"]),
                    "seconds": float(sec),
                    "seconds_min": float(sec.samples[0]),
                    "seconds_samples": list(sec.samples),
                }
                # the nullable comm + overlap columns (per-wave rows/bytes
                # shipped, the monolithic references, the carry-over
                # accounting) are derived from the stats registry — the
                # declarations in repro/obs/stats.py own the row schema
                row.update({k: stats.get(k)
                            for k in row_keys("comm", "overlap")})
                row.update(_compiled_cost_fields(eng, state, stats))
                rows.append(row)
                print("ROW " + json.dumps(rows[-1]), flush=True)
    if args.tn_sweep:
        _tn_sweep(args)


def _spawn(device_count: int, argv) -> list[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={device_count} "
        + env.get("XLA_FLAGS", "")).strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    p = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--run-inner", *argv],
                       capture_output=True, text=True, env=env)
    if p.returncode != 0:
        raise RuntimeError(f"inner sweep (d={device_count}) failed:\n"
                           + p.stderr[-4000:])
    rows = [json.loads(line[4:]) for line in p.stdout.splitlines()
            if line.startswith("ROW ")]
    for r in rows:
        if r.get("kind") == "tn":
            print(f"tn {r['model']:8s} {r['topology']:16s} "
                  f"n={r['n_agents']:6d} W={r['window']:4d} "
                  f"{r['tasks_per_s']:10.0f} tasks/s "
                  f"par={r['mean_parallelism']:6.2f}")
            continue
        comm = ("" if r.get("per_wave_comm_bytes") is None else
                f" comm/wave={r['per_wave_comm_bytes']:>8d}B"
                f" (halo={r['window_halo_bytes'] or '—'}B"
                f" full={r['full_state_bytes']}B)")
        ov = ("" if not r.get("overlap") else
              f" depth={r['mean_overlap_depth']:5.2f}"
              f" carry={r['carry_frontier_mean']:5.2f}")
        print(f"{r['model']:8s} {r['engine']:18s} W={r['window']:5d} "
              f"d={r['n_devices']} {r['tasks_per_s']:10.0f} tasks/s "
              f"par={r['mean_parallelism']:6.2f}{comm}{ov}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    # default sized so the monolithic halo rung beats the full state for
    # every scenario: the widest halo below is SIS at W=256 with
    # nr = max_degree+1 on the WS(n, 4, 0.1) graph (max_degree ~8-10)
    # -> ~256·(10+1+1) ≈ 3k rows, which must stay < n for that rung to
    # engage (the per-wave split rung has no width guard)
    ap.add_argument("--n", type=int, default=4096, help="agents")
    ap.add_argument("--windows", type=int, nargs="+", default=[128, 256])
    ap.add_argument("--devices", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--windows-per-run", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-sharded-1dev", action="store_true",
                    help="skip the sharded engine on 1-device meshes")
    ap.add_argument("--no-tn-sweep", dest="tn_sweep", action="store_false",
                    help="skip the fig3-style T(W, n) cost-model rows")
    ap.add_argument("--tn-sweep", action="store_true", default=True,
                    help=argparse.SUPPRESS)
    ap.add_argument("--tn-only", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="run under jax.profiler.trace, writing a "
                         "TensorBoard/Perfetto device profile per device "
                         "count into DIR (protocol phases show up via the "
                         "protocol.* named scopes)")
    ap.add_argument("--run-inner", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_engine.json"))
    ap.add_argument("--no-ledger", dest="ledger", action="store_false",
                    help="skip appending a benchmarks/ledger/ run record")
    ap.add_argument("--ledger-dir", default=None, metavar="DIR",
                    help="ledger directory (default benchmarks/ledger/)")
    args = ap.parse_args()
    if args.quick:
        args.n, args.windows, args.devices = 256, [64, 128], [1, 8]
        args.windows_per_run, args.repeats = 2, 1
        args.tn_sweep = False

    if args.run_inner:
        _inner(args)
        return

    def inner_argv(with_tn: bool, tn_only: bool = False) -> list[str]:
        return (["--n", str(args.n), "--windows",
                 *map(str, args.windows),
                 "--windows-per-run", str(args.windows_per_run),
                 "--repeats", str(args.repeats)]
                + (["--skip-sharded-1dev"] if args.skip_sharded_1dev
                   else [])
                + (["--profile", os.path.abspath(args.profile)]
                   if args.profile else [])
                + ([] if with_tn else ["--no-tn-sweep"])
                + (["--tn-only"] if tn_only else []))

    import jax  # after arg parsing: the parent keeps its default devices

    on_tpu = jax.default_backend() == "tpu"
    rows = []
    if on_tpu:
        # guarded TPU path: host-platform device forcing would hide the
        # real chips, so run the sweep in-process on the actual mesh
        import io
        from contextlib import redirect_stdout

        buf = io.StringIO()
        with redirect_stdout(buf):
            _inner(args)   # ends with the T(W, n) rows when tn_sweep is on
        rows = [json.loads(line[4:]) for line in buf.getvalue().splitlines()
                if line.startswith("ROW ")]
        print(buf.getvalue(), end="")
    else:
        for d in args.devices:
            # the T(W, n) rows are single-device by construction: attach
            # them to the d=1 subprocess so timings share its conditions
            rows.extend(_spawn(d, inner_argv(args.tn_sweep and d == 1)))
        if args.tn_sweep and 1 not in args.devices:
            # no d=1 lane requested: run the T(W, n) rows in their own
            # single-device subprocess rather than silently dropping them
            rows.extend(_spawn(1, inner_argv(True, tn_only=True)))

    from repro.obs import provenance

    engine_rows = [r for r in rows if r.get("kind") != "tn"]
    payload = {
        "meta": {
            # environment header (jax version, backend/device kind, git
            # sha, stats schema version) — rendered by report.py mabs.
            # NB: device_count is the parent process's view; the swept
            # mesh sizes are in device_counts below.
            "provenance": provenance(),
            "n_agents": args.n,
            "windows": [int(w) for w in args.windows],
            # from the rows, not the request: on TPU the sweep runs on the
            # one real mesh regardless of --devices
            "device_counts": sorted({r["n_devices"] for r in engine_rows}),
            "backend": "tpu" if on_tpu else "cpu",
            "virtual_devices": not on_tpu,
            "strict": True,
            "tn_sweep": {"families": list(TN_FAMILIES),
                         "n_agents": list(TN_AGENTS),
                         "windows": list(TN_WINDOWS)} if args.tn_sweep
                        else None,
        },
        "rows": rows,
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out} ({len(rows)} rows)")
    if args.ledger:
        # the append-only history: BENCH_engine.json is overwritten per
        # sweep, the ledger record is forever (report.py compare reads
        # either)
        try:
            from benchmarks.ledger import append_record
        except ImportError:  # run as a script: sys.path[0] is benchmarks/
            from ledger import append_record

        print(f"ledger record {append_record(payload, args.ledger_dir)}")


if __name__ == "__main__":
    main()
