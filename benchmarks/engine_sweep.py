"""Engine sweep: wavefront vs sharded throughput across device counts.

For each scenario in {voter, SIS, Axelrod} x window size x device count,
runs the same task stream through the ``wavefront`` (single-device),
``wavefront_overlap`` (cross-window overlapped waves), ``sharded``
(halo-exchange shard_map over the agent axis), ``sharded_overlap``
(overlap + pair halo) and ``sharded_replicated`` (full-state all_gather)
engines and reports end-to-end throughput (tasks/s, scheduling +
execution included), the schedule shape, for the sharded engines the
per-wave communication volume (gathered rows / payload bytes per device
vs the full state), and for the overlapped engines the carry-over
columns (mean/max overlap depth — tail waves of window k shared with
head waves of window k+1 — early-task counts and the carry frontier),
so BENCH_engine.json captures the halo comm win and the barrier-removal
win alongside tasks/s.

Device counts are realized per subprocess via
``--xla_force_host_platform_device_count`` so one invocation sweeps
several mesh sizes on CPU; on a real TPU backend the script uses the
actual devices instead (forcing host-platform devices would hide them)
and sweeps prefixes of ``jax.devices()``.

Emits BENCH_engine.json next to the repo root (or --out PATH):

  {"meta": {...}, "rows": [{"model", "engine", "window", "n_devices",
   "n_agents", "total_tasks", "tasks_per_s", "total_waves",
   "mean_parallelism", "seconds"}, ...]}

Run:  PYTHONPATH=src python benchmarks/engine_sweep.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys


def _inner(args) -> None:
    """Runs inside one subprocess with a fixed device count."""
    import jax

    from repro.engine import make_engine
    from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
    from repro.mabs.sis import SISModel
    from repro.mabs.voter import VoterModel
    from repro.topology import watts_strogatz
    from repro.utils.timing import median_time

    n = args.n
    topo = watts_strogatz(n, 4, 0.1, jax.random.key(0))
    models = {
        "voter": VoterModel(topo),
        "sis": SISModel(topo),
        "axelrod": AxelrodModel(AxelrodConfig(n_agents=n, n_features=3)),
    }
    rows = []
    for mname, model in models.items():
        state = model.init_state(jax.random.key(1))
        for window in args.windows:
            total = window * args.windows_per_run
            for ename in ("wavefront", "wavefront_overlap", "sharded",
                          "sharded_overlap", "sharded_replicated"):
                if ename.startswith("sharded") and jax.device_count() == 1 \
                        and args.skip_sharded_1dev:
                    continue
                eng = make_engine(ename, model, window=window)
                _, stats = eng.run(state, total, seed=2)  # warmup + stats
                sec = median_time(lambda: eng.run(state, total, seed=2)[0],
                                  repeats=args.repeats, warmup=0)
                rows.append({
                    "model": mname,
                    "engine": ename,
                    "window": int(window),
                    "n_devices": jax.device_count(),
                    "n_agents": int(n),
                    "total_tasks": int(total),
                    "tasks_per_s": float(total / sec),
                    "total_waves": int(stats["total_waves"]),
                    "mean_parallelism": float(stats["mean_parallelism"]),
                    "seconds": float(sec),
                    # comm-volume accounting (sharded engines only)
                    "halo": stats.get("halo"),
                    "per_wave_gather_rows": stats.get("per_wave_gather_rows"),
                    "per_wave_comm_bytes": stats.get("per_wave_comm_bytes"),
                    "full_state_bytes": stats.get("full_state_bytes"),
                    "comm_bytes_total": stats.get("comm_bytes_total"),
                    # carry-over accounting (overlapped engines only)
                    "overlap": stats.get("overlap"),
                    "mean_overlap_depth": stats.get("mean_overlap_depth"),
                    "max_overlap_depth": stats.get("max_overlap_depth"),
                    "overlap_tasks_early": stats.get("overlap_tasks_early"),
                    "carry_frontier_mean": stats.get("carry_frontier_mean"),
                    "carry_frontier_max": stats.get("carry_frontier_max"),
                })
                print("ROW " + json.dumps(rows[-1]), flush=True)


def _spawn(device_count: int, argv) -> list[dict]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={device_count} "
        + env.get("XLA_FLAGS", "")).strip()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(repo, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    p = subprocess.run([sys.executable, os.path.abspath(__file__),
                        "--run-inner", *argv],
                       capture_output=True, text=True, env=env)
    if p.returncode != 0:
        raise RuntimeError(f"inner sweep (d={device_count}) failed:\n"
                           + p.stderr[-4000:])
    rows = [json.loads(line[4:]) for line in p.stdout.splitlines()
            if line.startswith("ROW ")]
    for r in rows:
        comm = ("" if r.get("per_wave_comm_bytes") is None else
                f" comm/wave={r['per_wave_comm_bytes']:>8d}B"
                f" (full={r['full_state_bytes']}B)")
        ov = ("" if not r.get("overlap") else
              f" depth={r['mean_overlap_depth']:5.2f}"
              f" carry={r['carry_frontier_mean']:5.2f}")
        print(f"{r['model']:8s} {r['engine']:18s} W={r['window']:5d} "
              f"d={r['n_devices']} {r['tasks_per_s']:10.0f} tasks/s "
              f"par={r['mean_parallelism']:6.2f}{comm}{ov}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    # default sized so the halo beats the full state for every scenario:
    # the widest halo below is SIS at W=256 with nr = max_degree+1 on the
    # WS(n, 4, 0.1) graph (max_degree ~8-10) -> ~256·(10+1+1) ≈ 3k rows,
    # which must stay < n for the halo layout to engage
    ap.add_argument("--n", type=int, default=4096, help="agents")
    ap.add_argument("--windows", type=int, nargs="+", default=[128, 256])
    ap.add_argument("--devices", type=int, nargs="+", default=[1, 4, 8])
    ap.add_argument("--windows-per-run", type=int, default=4)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-sharded-1dev", action="store_true",
                    help="skip the sharded engine on 1-device meshes")
    ap.add_argument("--run-inner", action="store_true", help=argparse.SUPPRESS)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_engine.json"))
    args = ap.parse_args()
    if args.quick:
        args.n, args.windows, args.devices = 256, [64, 128], [1, 8]
        args.windows_per_run, args.repeats = 2, 1

    if args.run_inner:
        _inner(args)
        return

    inner_argv = (["--n", str(args.n), "--windows",
                   *map(str, args.windows),
                   "--windows-per-run", str(args.windows_per_run),
                   "--repeats", str(args.repeats)]
                  + (["--skip-sharded-1dev"] if args.skip_sharded_1dev
                     else []))

    import jax  # after arg parsing: the parent keeps its default devices

    on_tpu = jax.default_backend() == "tpu"
    rows = []
    if on_tpu:
        # guarded TPU path: host-platform device forcing would hide the
        # real chips, so run the sweep in-process on the actual mesh
        import io
        from contextlib import redirect_stdout

        buf = io.StringIO()
        with redirect_stdout(buf):
            _inner(args)
        rows = [json.loads(line[4:]) for line in buf.getvalue().splitlines()
                if line.startswith("ROW ")]
        print(buf.getvalue(), end="")
    else:
        for d in args.devices:
            rows.extend(_spawn(d, inner_argv))

    payload = {
        "meta": {
            "n_agents": args.n,
            "windows": [int(w) for w in args.windows],
            # from the rows, not the request: on TPU the sweep runs on the
            # one real mesh regardless of --devices
            "device_counts": sorted({r["n_devices"] for r in rows}),
            "backend": "tpu" if on_tpu else "cpu",
            "virtual_devices": not on_tpu,
            "strict": True,
        },
        "rows": rows,
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
