"""Reproduction of paper Fig. 3 — disease spreading T(s; n), C=6.

s = agents per subset (chain granularity). The paper's signature result:
T(s) spikes at small s (protocol overhead per tiny task), then stabilizes;
in the stable region T decreases with n, saturating around n=4; at small s
extra workers can *hurt*.

Costs: per-task execution cost measured from the vectorized SIR wave
executor (cost(s) = a + b·s), protocol overheads from DESCosts.

Output CSV: name,s,n_workers,T_mean,T_sem  (5 seeds).
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.core import DESCosts, ProtocolConfig, simulate_protocol
from repro.core.wavefront import WavefrontRunner
from repro.mabs.sir import SIRConfig, SIRModel
from repro.utils.timing import median_time


def calibrate_task_cost(n_agents=4_000, sizes=(10, 50, 200, 1000)):
    xs, ys = [], []
    for s in sizes:
        m = SIRModel(SIRConfig(n_agents=n_agents, k=14, subset_size=s))
        st = m.init_state(jax.random.key(0))
        w = min(64, 2 * m.cfg.n_subsets)
        runner = WavefrontRunner(m, window=w)
        t = median_time(lambda: runner._step(st, jax.random.key(1), 0),
                        repeats=3, warmup=1)
        xs.append(s)
        ys.append(t / w)
    A = np.vstack([np.ones(len(xs)), xs]).T
    (a, b), *_ = np.linalg.lstsq(A, np.asarray(ys), rcond=None)
    return max(a, 1e-8), max(b, 1e-10)


def run(n_steps=40, seeds=(0, 1, 2, 3, 4),
        sizes=(10, 20, 40, 50, 100, 200, 500, 1000),
        workers=(1, 2, 3, 4, 5), quick=False):
    if quick:
        n_steps, seeds, sizes = 10, (0, 1), (10, 50, 200, 1000)
    a, b = calibrate_task_cost()
    rows = []
    for s in sizes:
        cfg = SIRConfig(n_agents=4_000, k=14, subset_size=s,
                        p_si=0.8, p_ir=0.1, p_rs=0.3)
        m = SIRModel(cfg)
        n_tasks = cfg.tasks_per_step() * n_steps
        for n in workers:
            ts = []
            for seed in seeds:
                des = m.des_model(exec_cost=lambda r, s=s: a + b * s)
                r = simulate_protocol(
                    des, n_tasks,
                    config=ProtocolConfig(n_workers=n, tasks_per_cycle=6))
                ts.append(r.makespan)
            mean = float(np.mean(ts))
            sem = float(np.std(ts) / np.sqrt(len(ts)))
            rows.append(("fig3_sir", s, n, mean, sem))
            print(f"fig3_sir,s={s},n={n},{mean*1e3:.2f}ms,{sem*1e3:.3f}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
