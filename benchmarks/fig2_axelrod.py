"""Reproduction of paper Fig. 2 — cultural dynamics T(s=F; n), C=6.

Methodology (DESIGN.md §10): the calibrated discrete-event simulator
replays the exact worker-chain protocol; per-task model-execution cost is
*measured* on this machine from the jitted vectorized Axelrod executor
(cost(F) fit as a + b·F), and protocol overheads use the DESCosts
constants. Paper scale is 2e6 steps / N=1e4; default here is scaled down
(--tasks) since T is linear in task count in steady state — the claims
are about the SHAPE of T(s; n).

Output CSV: name,F,n_workers,T_mean,T_sem  (5 seeds, as in the paper).
"""
from __future__ import annotations

import sys

import jax
import numpy as np

from repro.core import DESCosts, ProtocolConfig, simulate_protocol
from repro.core.wavefront import WavefrontRunner
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
from repro.utils.timing import median_time


def calibrate_task_cost(n_agents=10_000, features=(3, 50, 150, 300, 500)):
    """Measure per-task execution cost of the vectorized executor and fit
    cost(F) = a + b·F."""
    xs, ys = [], []
    for f in features:
        m = AxelrodModel(AxelrodConfig(n_agents=n_agents, n_features=f))
        st = m.init_state(jax.random.key(0))
        runner = WavefrontRunner(m, window=256)
        t = median_time(lambda: runner._step(st, jax.random.key(1), 0),
                        repeats=3, warmup=1)
        xs.append(f)
        ys.append(t / 256.0)      # per-task cost of the vectorized engine
    A = np.vstack([np.ones(len(xs)), xs]).T
    (a, b), *_ = np.linalg.lstsq(A, np.asarray(ys), rcond=None)
    return max(a, 1e-8), max(b, 1e-10)


def run(n_tasks=30_000, seeds=(0, 1, 2, 3, 4), features=(3, 50, 150, 300, 500),
        workers=(1, 2, 3, 4, 5), quick=False):
    if quick:
        n_tasks, seeds = 5_000, (0, 1)
    a, b = calibrate_task_cost()
    rows = []
    for f in features:
        for n in workers:
            ts = []
            for seed in seeds:
                m = AxelrodModel(AxelrodConfig(n_agents=10_000,
                                               n_features=f))
                des = m.des_model(seed=seed,
                                  exec_cost=lambda r, f=f: a + b * f)
                r = simulate_protocol(
                    des, n_tasks,
                    config=ProtocolConfig(n_workers=n, tasks_per_cycle=6))
                ts.append(r.makespan)
            mean = float(np.mean(ts))
            sem = float(np.std(ts) / np.sqrt(len(ts)))
            rows.append(("fig2_axelrod", f, n, mean, sem))
            print(f"fig2_axelrod,F={f},n={n},{mean*1e3:.2f}ms,{sem*1e3:.3f}")
    return rows


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
