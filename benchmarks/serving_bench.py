"""Serving-engine benchmark: continuous batching (the paper's protocol on
LLM inference) vs naive one-request-at-a-time serving.

Reports wall time and protocol statistics (mean wave size = achieved
batching parallelism — the serving analogue of Fig. 2/3's worker scaling).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine


def run(n_requests=8, max_new=8, quick=False):
    if quick:
        n_requests, max_new = 4, 4
    cfg = ARCHS["smollm-360m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=int(rng.randint(4, 24)))
               .astype(np.int32) for _ in range(n_requests)]

    # --- naive sequential serving ---
    import jax.numpy as jnp

    pre = jax.jit(model.prefill)
    dec = jax.jit(model.decode_step)
    t0 = time.perf_counter()
    for p in prompts:
        states = model.init_states(1, max_len=64)
        lp, states = pre(params, {"tokens": jnp.asarray(p)[None]}, states)
        tok = int(jnp.argmax(lp[0]))
        for _ in range(max_new - 1):
            ld, states = dec(params, jnp.asarray([[tok]], jnp.int32),
                             states)
            tok = int(jnp.argmax(ld[0]))
    t_seq = time.perf_counter() - t0

    # --- protocol-scheduled continuous batching ---
    eng = ServingEngine(model, params, n_slots=4, max_len=64,
                        prefill_chunk=16)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    t0 = time.perf_counter()
    eng.run()
    t_eng = time.perf_counter() - t0

    tokens = n_requests * max_new
    mean_wave = float(np.mean(eng.wave_sizes))
    print(f"serving_sequential,{t_seq/tokens*1e6:.0f},"
          f"{tokens/t_seq:.1f} tok/s")
    print(f"serving_protocol,{t_eng/tokens*1e6:.0f},"
          f"{tokens/t_eng:.1f} tok/s; mean_wave={mean_wave:.2f}; "
          f"iters={eng.iterations}")
    return [("serving_sequential", t_seq / tokens * 1e6, f"{tokens/t_seq:.1f} tok/s"),
            ("serving_protocol", t_eng / tokens * 1e6,
             f"{tokens/t_eng:.1f} tok/s mean_wave={mean_wave:.2f}")]


if __name__ == "__main__":
    run()
