"""Benchmark harness — one entry per paper figure/table plus framework
benches. Prints ``name,us_per_call,derived`` CSV lines.

  fig2_axelrod   paper Fig. 2  (T vs s=F for n in 1..5, calibrated DES)
  fig3_sir       paper Fig. 3  (T vs s=subset size)
  kernels        per-kernel micro-benchmarks
  serving        protocol-scheduled continuous batching vs sequential
  roofline       summary of dry-run artifacts (if present)

``python -m benchmarks.run``         — full run
``python -m benchmarks.run --quick`` — CI-sized run
``python -m benchmarks.run fig3``    — one section
"""
from __future__ import annotations

import sys


def main() -> None:
    quick = "--quick" in sys.argv
    only = None
    for a in sys.argv[1:]:
        if not a.startswith("-"):
            only = a

    def want(name):
        return only is None or only == name

    if want("fig2"):
        print("# --- fig2_axelrod: name,s(F),n,T_mean_ms,T_sem_ms ---")
        from benchmarks.fig2_axelrod import run as fig2

        fig2(quick=quick)
    if want("fig3"):
        print("# --- fig3_sir: name,s,n,T_mean_ms,T_sem_ms ---")
        from benchmarks.fig3_sir import run as fig3

        fig3(quick=quick)
    if want("kernels"):
        print("# --- kernels: name,us_per_call,derived ---")
        from benchmarks.kernels_bench import run_all as kb

        kb()
    if want("serving"):
        print("# --- serving: name,us_per_token,derived ---")
        from benchmarks.serving_bench import run as sb

        sb(quick=quick)
    if want("roofline"):
        import glob
        import os

        if glob.glob(os.path.join("artifacts/dryrun", "*.json")):
            print("# --- roofline (from dry-run artifacts) ---")
            from benchmarks.roofline import main as rl

            rl()
        else:
            print("# roofline: no artifacts/dryrun/*.json — run "
                  "python -m repro.launch.dryrun --all first")


if __name__ == "__main__":
    main()
