"""Render benchmark artifacts as markdown tables.

Four report families share this entry point:

  * LM dry-run/roofline (the historic default):
      PYTHONPATH=src python -m benchmarks.report [artifacts/dryrun]
  * MABS protocol benchmarks — aggregates BENCH_topology.json and
    BENCH_engine.json (scheduling parallelism, sparse-builder scaling,
    engine throughput + halo comm volume) into one markdown report:
      PYTHONPATH=src python -m benchmarks.report mabs [repo-root]
  * Schedule "explain" — decodes one exported protocol trace
    (repro.obs span tracer -> Chrome trace-event JSON) into the
    schedule's shape: wave-size histogram, critical-path length,
    per-device load imbalance and the comm-ledger breakdown per rung:
      PYTHONPATH=src python -m benchmarks.report explain TRACE.json
  * Trace timing summary — where the traced run's wall time went
    (schedule vs execute vs boundary, per-window table):
      PYTHONPATH=src python -m benchmarks.report trace TRACE.json
  * Benchmark regression compare — thresholded per-row verdicts between
    two BENCH_engine.json artifacts / ledger records (same schema);
    ``--gate`` exits nonzero on a regression (CI's bench-regression job):
      PYTHONPATH=src python -m benchmarks.report compare OLD NEW [--gate]

``explain`` dispatches on content: a Chrome-trace payload renders the
schedule shape (above); a BENCH_engine.json / ledger record renders the
compiled-cost MABS roofline and the fitted T(W, n) cost model.

Writes markdown to stdout (EXPERIMENTS.md / docs embed the output).
"""
from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.roofline import analytic_costs, roofline_row

ORDER = ["h2o-danube-3-4b", "smollm-360m", "qwen1.5-32b", "deepseek-7b",
         "rwkv6-3b", "seamless-m4t-medium", "arctic-480b",
         "qwen3-moe-235b-a22b", "hymba-1.5b", "internvl2-76b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(artifact_dir, opt=False):
    recs = {}
    for path in glob.glob(os.path.join(artifact_dir, "*.json")):
        with open(path) as f:
            r = json.load(f)
        if bool(r.get("opt")) != opt:
            continue
        recs[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(recs, mesh):
    print(f"\n#### Mesh: {mesh} "
          f"({'2×16×16 = 512 chips' if mesh == 'multi' else '16×16 = 256 chips'})\n")
    print("| arch | shape | status | compile s | args GiB/dev | temp GiB/dev"
          " | HLO GFLOPs (raw) | wire GiB/dev | dominant colls |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None:
                print(f"| {arch} | {shape} | MISSING | | | | | | |")
                continue
            if r["status"] != "ok":
                reason = r.get("skip_reason") or r.get("status")
                print(f"| {arch} | {shape} | skip | | | | | |"
                      f" {reason[:60]} |")
                continue
            colls = r["collectives"]["wire_bytes"]
            dom = max(colls, key=colls.get) if colls else "-"
            print(f"| {arch} | {shape} | ok | {r['compile_s']:.1f} "
                  f"| {fmt_bytes(r['memory']['argument_bytes'])} "
                  f"| {fmt_bytes(r['memory']['temp_bytes'])} "
                  f"| {r['cost_analysis']['flops']/1e9:.1f} "
                  f"| {fmt_bytes(r['collectives']['total_wire_bytes'])} "
                  f"| {dom} |")


def roofline_table(recs, mesh="single"):
    print("\n| arch | shape | compute s | memory s | collective s | dominant"
          " | MODEL_TFLOPs | useful/analytic | roofline frac | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for arch in ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh))
            if r is None or r.get("status") != "ok":
                if r is not None and r.get("status") == "skipped":
                    print(f"| {arch} | {shape} | — | — | — | — | — | — | — |"
                          f" skipped: sub-quadratic-only shape |")
                continue
            row = roofline_row(r)
            note = {
                "compute": "compute-bound: near ideal if comms overlap",
                "memory": "HBM-bound: fuse/cache-resident or quantize",
                "collective": "comm-bound: reshard or overlap collectives",
            }[row["dominant"]]
            print(f"| {arch} | {shape} | {row['t_compute_s']:.4f} "
                  f"| {row['t_memory_s']:.4f} | {row['t_collective_s']:.4f} "
                  f"| **{row['dominant']}** "
                  f"| {row['model_flops']/1e12:.1f} "
                  f"| {row['useful_vs_analytic']:.2f} "
                  f"| {row['roofline_fraction']:.3f} | {note} |")


# --------------------------------------------------------------------------
# MABS protocol report (BENCH_topology.json + BENCH_engine.json)


def _load_bench(root, name):
    path = os.path.join(root, name)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def _fmt_kb(b):
    if b is None:
        return "—"
    return f"{b / 1024:.1f} KiB" if b >= 1024 else f"{b} B"


def mabs_topology_tables(bench):
    meta, rows = bench["meta"], bench["rows"]
    sched = [r for r in rows if r.get("kind", "schedule") == "schedule"]
    builds = [r for r in rows if r.get("kind") == "build"]
    if sched:
        print(f"\n#### Scheduling parallelism "
              f"(n = {meta.get('n_nodes')}, backend = "
              f"{meta.get('backend')}, strict rule)\n")
        print("| topology | model | W | waves | mean par | conflict dens"
              " | sched ms/window |")
        print("|---|---|---|---|---|---|---|")
        for r in sched:
            print(f"| {r['topology']} | {r['model']} | {r['window']} "
                  f"| {r['n_waves']} | {r['mean_parallelism']:.2f} "
                  f"| {r['conflict_density']:.4f} "
                  f"| {r['sched_seconds'] * 1e3:.2f} |")
    if builds:
        print("\n#### Sparse builder scaling "
              "(edge-list path, no [n, n] allocation)\n")
        print("| topology | n | build s | edges | max deg "
              "| SIS sched ms/window |")
        print("|---|---|---|---|---|---|")
        for r in builds:
            sched_ms = (f"{r['sched_seconds'] * 1e3:.2f}"
                        if "sched_seconds" in r else "—")
            print(f"| {r['topology']} | {r['n_nodes']:,} "
                  f"| {r['build_seconds']:.2f} | {r['n_edges']:,} "
                  f"| {r['max_degree']} | {sched_ms} |")


def mabs_engine_table(bench):
    meta, rows = bench["meta"], bench["rows"]
    engine_rows = [r for r in rows if r.get("kind", "engine") == "engine"]
    tn_rows = [r for r in rows if r.get("kind") == "tn"]
    print(f"\n#### Engine throughput, comm volume and window overlap "
          f"(n = {meta.get('n_agents')} agents, backend = "
          f"{meta.get('backend')}"
          f"{', virtual devices' if meta.get('virtual_devices') else ''})\n")
    print("| model | W | devices | engine | tasks/s | mean par "
          "| comm/wave/device | window halo | full state "
          "| red. ×halo | red. ×full | overlap depth | carry frontier |")
    print("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in engine_rows:
        comm = r.get("per_wave_comm_bytes")
        halo_ref = r.get("window_halo_bytes")
        full = r.get("full_state_bytes")
        # red. ×halo: the per-wave split's win over the monolithic
        # window/pair halo; red. ×full: any halo layout's win over the
        # replicated all_gather
        red_h = (f"{r['comm_reduction_vs_window_halo']:.1f}×"
                 if r.get("comm_reduction_vs_window_halo")
                 and r.get("halo") else "—")
        red_f = (f"{full / comm:.1f}×" if comm and full
                 and r.get("halo") else "—")
        if r.get("overlap"):
            # mean/max waves of window k shared with window k+1's head,
            # and the carry-over level floor the cross block imposed
            depth = (f"{r['mean_overlap_depth']:.2f} "
                     f"(max {r['max_overlap_depth']})")
            carry = (f"{r['carry_frontier_mean']:.2f} "
                     f"(max {r['carry_frontier_max']})")
        else:
            depth = carry = "—"
        print(f"| {r['model']} | {r['window']} | {r['n_devices']} "
              f"| {r['engine']} | {r['tasks_per_s']:,.0f} "
              f"| {r['mean_parallelism']:.2f} | {_fmt_kb(comm)} "
              f"| {_fmt_kb(halo_ref)} | {_fmt_kb(full)} "
              f"| {red_h} | {red_f} | {depth} | {carry} |")
    if tn_rows:
        mabs_tn_table(tn_rows)


def mabs_tn_table(rows):
    """fig3-style T(W, n) cost-model sweep: wavefront seconds per task
    for voter/SIS across the topology families (the MABS analog of the
    paper's T(s, n) subset-size figure)."""
    print("\n#### Cost-model T(W, n) sweep "
          "(wavefront engine, µs per task)\n")
    byn = sorted({r["n_agents"] for r in rows})
    print("| model | topology | W | "
          + " | ".join(f"n={n:,}" for n in byn) + " | waves/window |")
    print("|---|---|---|" + "---|" * (len(byn) + 1))
    keys = sorted({(r["model"], r["topology"], r["window"])
                   for r in rows})
    for model, topo, window in keys:
        cells, waves = [], []
        for n in byn:
            match = [r for r in rows
                     if (r["model"], r["topology"], r["window"],
                         r["n_agents"]) == (model, topo, window, n)]
            if match:
                r = match[0]
                cells.append(f"{1e6 * r['seconds'] / r['total_tasks']:.1f}")
                waves.append(f"{r['total_waves'] / max(r['total_tasks'] // r['window'], 1):.1f}")
            else:
                cells.append("—")
                waves.append("—")
        # one waves-per-window entry per n column (schedule contention
        # varies with n), in the same order as the time cells
        print(f"| {model} | {topo} | {window} | "
              + " | ".join(cells) + f" | {'/'.join(waves)} |")


def _provenance_line(meta):
    """One-line environment header (benchmarks stamp it into meta)."""
    p = (meta or {}).get("provenance")
    if not p:
        return None
    return (f"jax {p.get('jax_version')} · backend {p.get('backend')} "
            f"({p.get('device_kind')} ×{p.get('device_count')}) · "
            f"git {p.get('git_sha') or 'unknown'} · "
            f"stats v{p.get('stats_version')} · {p.get('timestamp')}")


def mabs_report(root="."):
    print("### MABS protocol benchmarks (generated by benchmarks/report.py)")
    topo = _load_bench(root, "BENCH_topology.json")
    eng = _load_bench(root, "BENCH_engine.json")
    if topo is None and eng is None:
        print("\n(no BENCH_topology.json / BENCH_engine.json found under "
              f"{os.path.abspath(root)} — run benchmarks/topology_sweep.py "
              "and benchmarks/engine_sweep.py first)")
        return
    for name, bench in (("topology", topo), ("engine", eng)):
        line = _provenance_line(bench.get("meta")) if bench else None
        if line:
            print(f"\n*{name} sweep: {line}*")
    if topo is not None:
        mabs_topology_tables(topo)
    if eng is not None:
        mabs_engine_table(eng)


# --------------------------------------------------------------------------
# protocol-trace reports (repro.obs span tracer -> Chrome trace JSON)


def _load_trace(path):
    """Load + schema-validate an exported protocol trace; returns the
    event list."""
    from repro.obs import validate_chrome_trace

    with open(path) as f:
        payload = json.load(f)
    validate_chrome_trace(payload)
    return payload["traceEvents"] if isinstance(payload, dict) else payload


def _span_durations(events):
    """Pair B/E events per (pid, tid) lane into (name, ts, dur, args)
    tuples (the validator guarantees proper nesting)."""
    spans = []
    stacks: dict = {}
    for ev in sorted((e for e in events if e.get("ph") in ("B", "E")),
                     key=lambda e: e["ts"]):
        lane = stacks.setdefault((ev["pid"], ev["tid"]), [])
        if ev["ph"] == "B":
            lane.append(ev)
        else:
            b = lane.pop()
            spans.append((b["name"], b["ts"], ev["ts"] - b["ts"],
                          b.get("args", {})))
    return spans


def _bar(frac, width=30):
    n = int(round(frac * width))
    return "█" * n + "·" * (width - n)


def _trace_header(events):
    runs = [e for e in events if e["name"] == "run" and e["ph"] == "B"]
    if runs:
        a = runs[0].get("args", {})
        print(f"\nengine `{a.get('engine')}` · window {a.get('window')} · "
              f"{a.get('total_tasks')} tasks · "
              f"overlap {'on' if a.get('overlap') else 'off'}")
    return runs[0].get("args", {}) if runs else {}


def explain_report(path):
    """Content dispatch: a BENCH/ledger payload ({"meta", "rows"})
    renders the compiled-cost roofline + T(W, n) fit; a Chrome-trace
    payload renders the schedule's shape."""
    with open(path) as f:
        payload = json.load(f)
    if isinstance(payload, dict) and "rows" in payload:
        bench_explain(payload, path)
        return
    trace_explain(path)


def bench_explain(bench, path):
    """The static-cost half of explain: MABS roofline (compiled cost
    bounds vs measured seconds per engine row) and the fitted T(W, n)
    cost model with per-family residuals."""
    from benchmarks.roofline import fit_tn_cost_model, mabs_roofline_rows

    print(f"### Bench explain — {os.path.basename(path)}")
    line = _provenance_line(bench.get("meta"))
    if line:
        print(f"\n*{line}*")

    roof = mabs_roofline_rows(bench)
    if roof:
        backend = bench.get("meta", {}).get("backend", "cpu")
        print(f"\n#### MABS roofline (compiled costs, {backend} peaks; "
              "bound = max of the three terms)\n")
        print("| model | engine | W | dev | executor | compute s "
              "| memory s | collective s | dominant | bound s "
              "| measured s | ×bound | hlo/ledger |")
        print("|" + "---|" * 13)
        for r in roof:
            ratio = r.get("coll_ledger_ratio")
            print(f"| {r['model']} | {r['engine']} | {r['window']} "
                  f"| {r['n_devices']} | {r['executor']} "
                  f"| {r['t_compute_s']:.2e} | {r['t_memory_s']:.2e} "
                  f"| {r['t_collective_s']:.2e} | **{r['dominant']}** "
                  f"| {r['bound_s']:.2e} | {r['measured_s']:.2e} "
                  f"| {r['above_bound']:.1f}× "
                  f"| {f'{ratio:.3f}' if ratio is not None else '—'} |")
        bad = [r for r in roof if r.get("coll_ledger_ratio") is not None
               and abs(r["coll_ledger_ratio"] - 1.0) > 1e-9]
        print(f"\nhlo/ledger = HLO-parsed collective bytes / runtime comm "
              f"ledger — {'ALL EXACT (1.000)' if not bad else f'{len(bad)} MISMATCHED rows (bug detector fired)'} "
              f"on {sum(1 for r in roof if r.get('coll_ledger_ratio') is not None)} "
              "cross-checked rows")
    else:
        print("\n(no engine rows with compiled-cost telemetry — rerun "
              "benchmarks/engine_sweep.py to capture the `cost` field)")

    tn_rows = [r for r in bench.get("rows", []) if r.get("kind") == "tn"]
    if tn_rows:
        fits = fit_tn_cost_model(tn_rows)
        print("\n#### Fitted T(W, n) cost model "
              "(per model, least squares over the tn sweep)\n")
        print("| model | rows | c_sched [s/W²] | c_wave [s/wave] "
              "| c_agent [s/(wave·n)] | c0 [s] | R² | rel RMS |")
        print("|---|---|---|---|---|---|---|---|")
        for f_ in fits:
            c = f_["coef"]
            print(f"| {f_['model']} | {f_['n_rows']} "
                  f"| {c['c_sched[s/W^2]']:.3e} | {c['c_wave[s/wave]']:.3e} "
                  f"| {c['c_agent[s/(wave*n)]']:.3e} | {c['c0[s]']:.3e} "
                  f"| {f_['r2']:.3f} | {f_['rms_rel']:.3f} |")
        print("\n| model | topology family | rows | residual rel RMS |")
        print("|---|---|---|---|")
        for f_ in fits:
            for fam, res in f_["residuals_by_family"].items():
                print(f"| {f_['model']} | {fam} | {res['n']} "
                      f"| {res['rms_rel']:.3f} |")
    else:
        print("\n(no kind:\"tn\" rows — run the sweep without "
              "--no-tn-sweep to fit the T(W, n) cost model)")


def trace_explain(path):
    """Decode one protocol trace into the schedule's shape."""
    events = _load_trace(path)
    print(f"### Schedule explain — {os.path.basename(path)}")
    run_args = _trace_header(events)
    waves = [e for e in events
             if e.get("ph") == "X" and e["name"] == "wave"]
    gathers = [e for e in events
               if e.get("ph") == "X" and e["name"] == "halo_gather"]
    if not waves:
        print("\n(no wave spans in this trace — nothing to explain)")
        return

    # ---- wave-size histogram (log2 buckets) + critical path
    widths = [int(e["args"].get("width", 0)) for e in waves]
    total_tasks = sum(widths)
    n_waves = len(waves)
    print(f"\n#### Wave-size histogram ({n_waves} executed waves, "
          f"{total_tasks} tasks)\n")
    buckets: dict = {}
    for w in widths:
        b = 0 if w == 0 else 1 << max(w - 1, 0).bit_length()
        buckets[b] = buckets.get(b, 0) + 1
    print("| wave width ≤ | waves | share |")
    print("|---|---|---|")
    for b in sorted(buckets):
        frac = buckets[b] / n_waves
        print(f"| {b} | {buckets[b]} | `{_bar(frac)}` {frac:5.1%} |")
    # the waves of a run execute strictly in sequence (each is one fused
    # vectorized step), so the executed wave count IS the schedule's
    # critical-path length
    print(f"\ncritical path: **{n_waves} waves** for {total_tasks} tasks "
          f"-> mean parallelism {total_tasks / max(n_waves, 1):.2f} "
          f"tasks/wave")
    if run_args.get("window"):
        seq = total_tasks  # the oracle's critical path: one task per step
        print(f"(sequential baseline {seq} steps; wavefront speedup "
              f"upper bound {seq / max(n_waves, 1):.2f}×)")

    # ---- per-device load imbalance (sharded traces carry owned counts)
    owned = [e["args"]["owned"] for e in waves if "owned" in e["args"]]
    if owned:
        d = len(owned[0])
        totals = [sum(o[i] for o in owned) for i in range(d)]
        mean = sum(totals) / d
        print(f"\n#### Per-device load ({d} devices, owned tasks/device)\n")
        print("| device | owned tasks | vs mean |")
        print("|---|---|---|")
        for i, t in enumerate(totals):
            rel = t / mean if mean else 0.0
            print(f"| {i} | {t} | `{_bar(min(rel / 2, 1.0))}` {rel:4.2f}× |")
        # per-wave imbalance: max/mean owned across devices, averaged
        per_wave = [max(o) * len(o) / max(sum(o), 1) for o in owned
                    if sum(o)]
        if per_wave:
            print(f"\nper-wave imbalance (max/mean owned): mean "
                  f"{sum(per_wave) / len(per_wave):.2f}×, "
                  f"worst {max(per_wave):.2f}×  (1.0× = perfectly even)")

    # ---- comm-ledger breakdown per rung
    if gathers:
        print("\n#### Comm ledger (per-device receive volume, by "
              "comm-ladder rung)\n")
        rungs: dict = {}
        for e in gathers:
            a = e["args"]
            r = rungs.setdefault(a.get("rung", "?"),
                                 {"waves": 0, "rows": 0, "bytes": 0})
            r["waves"] += 1
            r["rows"] += int(a.get("rows", 0))
            r["bytes"] += int(a.get("bytes", 0))
        total_b = sum(r["bytes"] for r in rungs.values()) or 1
        print("| rung | waves | rows | bytes | share |")
        print("|---|---|---|---|---|")
        for name in ("split", "window_halo", "pair_halo", "full_state"):
            if name not in rungs:
                continue
            r = rungs[name]
            frac = r["bytes"] / total_b
            print(f"| {name} | {r['waves']} | {r['rows']:,} "
                  f"| {_fmt_kb(r['bytes'])} | `{_bar(frac)}` {frac:5.1%} |")
    else:
        print("\n(no halo_gather spans — single-device trace, no comm)")


def trace_report(path):
    """Where a traced run's wall time went (host-fenced span times)."""
    events = _load_trace(path)
    print(f"### Trace timing — {os.path.basename(path)}")
    _trace_header(events)
    spans = _span_durations(events)
    if not spans:
        print("\n(no B/E spans in this trace)")
        return
    run_dur = sum(d for n, _, d, _ in spans if n == "run") or 1.0
    by_name: dict = {}
    for name, _, dur, _ in spans:
        if name == "run":
            continue
        c, t = by_name.get(name, (0, 0.0))
        by_name[name] = (c + 1, t + dur)
    print("\n#### Phase totals (host wall time, fenced — tracing "
          "serializes the window pipeline)\n")
    print("| phase | spans | total ms | share of run |")
    print("|---|---|---|---|")
    for name, (c, t) in sorted(by_name.items(), key=lambda kv: -kv[1][1]):
        frac = t / run_dur
        print(f"| {name} | {c} | {t / 1e3:.2f} "
              f"| `{_bar(frac)}` {frac:5.1%} |")
    # per-window schedule-vs-execute split
    windows: dict = {}
    for name, _, dur, args in spans:
        if name not in ("schedule", "execute", "boundary"):
            continue
        w = windows.setdefault(args.get("index", "?"),
                               {"n_waves": None, "rung": None})
        w[name] = w.get(name, 0.0) + dur
        if name == "execute":
            w["n_waves"] = args.get("n_waves")
            w["rung"] = args.get("rung")
    if windows:
        print("\n#### Per-window split\n")
        print("| window | schedule ms | boundary ms | execute ms "
              "| waves | rung |")
        print("|---|---|---|---|---|---|")
        for i in sorted(windows, key=str):
            w = windows[i]
            sch = w.get("schedule")
            bnd = w.get("boundary")
            exe = w.get("execute")
            print(f"| {i} | {sch / 1e3:.2f}" if sch is not None
                  else f"| {i} | —", end="")
            print(f" | {bnd / 1e3:.2f}" if bnd is not None else " | —",
                  end="")
            print(f" | {exe / 1e3:.2f}" if exe is not None else " | —",
                  end="")
            print(f" | {w['n_waves'] if w['n_waves'] is not None else '—'} "
                  f"| {w['rung'] or '—'} |")


# --------------------------------------------------------------------------
# benchmark regression compare (BENCH artifacts / ledger records)

#: row identity for the compare join — everything that pins a scenario
COMPARE_KEY = ("kind", "model", "engine", "topology", "window",
               "n_devices", "n_agents")

#: default relative threshold on tasks/s before a row is verdicted
COMPARE_THRESHOLD = 0.15


def _row_key(r):
    return tuple(r.get(k) for k in COMPARE_KEY)


def _rel_spread(r):
    """Dispersion of one row's timing repeats: (max-min)/median over
    ``seconds_samples`` (0.0 when the row predates the samples column)."""
    samples = r.get("seconds_samples") or []
    med = r.get("seconds")
    if len(samples) < 2 or not med:
        return 0.0
    return (max(samples) - min(samples)) / med


def compare_benches(old: dict, new: dict,
                    threshold: float = COMPARE_THRESHOLD) -> dict:
    """Thresholded per-row verdicts between two bench payloads.

    Joins rows on ``COMPARE_KEY`` and verdicts the ``tasks_per_s`` ratio
    new/old: ``regressed`` below ``1 - t``, ``improved`` above ``1 + t``,
    ``neutral`` between — where ``t`` is the *effective* threshold:
    ``max(threshold, 2 × timing spread)`` of whichever side is noisier
    (dispersion-aware — a noisy row needs a bigger move to be verdicted).
    A provenance backend mismatch (cpu baseline vs tpu run, or vice
    versa) makes the whole comparison ``warn_only``: verdicts still
    render, but the gate never fails on them."""
    def backend(b):
        meta = b.get("meta", {})
        return (meta.get("provenance") or {}).get("backend") \
            or meta.get("backend")

    warn_only = (backend(old) is not None and backend(new) is not None
                 and backend(old) != backend(new))
    old_rows = {_row_key(r): r for r in old.get("rows", [])}
    results = []
    for r in new.get("rows", []):
        key = _row_key(r)
        base = old_rows.pop(key, None)
        if base is None:
            results.append({"key": key, "verdict": "new",
                            "ratio": None, "threshold": None})
            continue
        spread = max(_rel_spread(r), _rel_spread(base))
        eff = max(threshold, 2.0 * spread)
        o, n = base.get("tasks_per_s"), r.get("tasks_per_s")
        if not o or not n:
            verdict, ratio = "incomparable", None
        else:
            ratio = n / o
            verdict = ("regressed" if ratio < 1.0 - eff
                       else "improved" if ratio > 1.0 + eff
                       else "neutral")
        results.append({"key": key, "verdict": verdict, "ratio": ratio,
                        "threshold": eff, "old": o, "new": n,
                        "spread": spread})
    counts: dict = {}
    for r in results:
        counts[r["verdict"]] = counts.get(r["verdict"], 0) + 1
    return {
        "warn_only": warn_only,
        "old_backend": backend(old), "new_backend": backend(new),
        "rows": results,
        "counts": counts,
        "unmatched_old": len(old_rows),
        "regressed": [r for r in results if r["verdict"] == "regressed"],
    }


def compare_report(old_path: str, new_path: str,
                   threshold: float = COMPARE_THRESHOLD,
                   gate: bool = False) -> int:
    """Render the compare as markdown; returns the process exit code
    (nonzero only under ``--gate`` with a non-warn-only regression)."""
    with open(old_path) as f:
        old = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    cmp = compare_benches(old, new, threshold)
    print(f"### Bench compare — {os.path.basename(old_path)} → "
          f"{os.path.basename(new_path)}")
    for name, b in (("old", old), ("new", new)):
        line = _provenance_line(b.get("meta"))
        if line:
            print(f"\n*{name}: {line}*")
    if cmp["warn_only"]:
        print(f"\n**backend mismatch ({cmp['old_backend']} → "
              f"{cmp['new_backend']}): warn-only — verdicts are "
              "informational, the gate will not fail**")
    print(f"\nthreshold {threshold:.0%} relative on tasks/s, widened per "
          "row to 2× its timing spread (seconds_samples)\n")
    print("| kind | model | engine | topology | W | dev | old tasks/s "
          "| new tasks/s | ratio | eff. thr | verdict |")
    print("|" + "---|" * 11)
    marker = {"regressed": "**regressed**", "improved": "improved",
              "neutral": "neutral", "new": "new row",
              "incomparable": "incomparable"}
    for r in sorted(cmp["rows"],
                    key=lambda r: (r["verdict"] != "regressed", r["key"])):
        kind, model, engine, topo, w, dev, n = r["key"]
        ratio = f"{r['ratio']:.2f}×" if r["ratio"] is not None else "—"
        thr = (f"{r['threshold']:.0%}" if r["threshold"] is not None
               else "—")
        old_v = f"{r['old']:,.0f}" if r.get("old") else "—"
        new_v = f"{r['new']:,.0f}" if r.get("new") else "—"
        print(f"| {kind} | {model} | {engine or '—'} | {topo or '—'} "
              f"| {w} | {dev or '—'} | {old_v} | {new_v} | {ratio} "
              f"| {thr} | {marker[r['verdict']]} |")
    c = cmp["counts"]
    print(f"\nsummary: {c.get('regressed', 0)} regressed · "
          f"{c.get('improved', 0)} improved · {c.get('neutral', 0)} "
          f"neutral · {c.get('new', 0)} new · "
          f"{cmp['unmatched_old']} baseline rows not re-measured")
    if cmp["regressed"] and not cmp["warn_only"]:
        if gate:
            print("\nGATE: FAIL (regressions above, exit 1)")
            return 1
        print("\n(regressions above; pass --gate to make this fail)")
    elif gate:
        print("\nGATE: PASS")
    return 0


def compare_main(argv) -> int:
    import argparse

    ap = argparse.ArgumentParser(prog="benchmarks.report compare")
    ap.add_argument("old", help="baseline BENCH json / ledger record")
    ap.add_argument("new", help="candidate BENCH json / ledger record")
    ap.add_argument("--threshold", type=float, default=COMPARE_THRESHOLD,
                    help="relative tasks/s threshold before a verdict "
                         f"(default {COMPARE_THRESHOLD})")
    ap.add_argument("--gate", action="store_true",
                    help="exit nonzero on a non-warn-only regression")
    a = ap.parse_args(argv)
    return compare_report(a.old, a.new, a.threshold, a.gate)


def main():
    if len(sys.argv) > 1 and sys.argv[1] == "compare":
        sys.exit(compare_main(sys.argv[2:]))
    if len(sys.argv) > 1 and sys.argv[1] == "mabs":
        mabs_report(sys.argv[2] if len(sys.argv) > 2 else ".")
        return
    if len(sys.argv) > 1 and sys.argv[1] in ("explain", "trace"):
        if len(sys.argv) < 3:
            sys.exit(f"usage: benchmarks.report {sys.argv[1]} TRACE.json")
        (explain_report if sys.argv[1] == "explain"
         else trace_report)(sys.argv[2])
        return
    d = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    recs = load(d)
    print("### §Dry-run results (generated by benchmarks/report.py)")
    for mesh in ("single", "multi"):
        dryrun_table(recs, mesh)
    print("\n### §Roofline (single-pod, 256 chips)")
    roofline_table(recs, "single")


if __name__ == "__main__":
    main()
