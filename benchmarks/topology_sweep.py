"""Topology sweep: wave parallelism and scheduling overhead vs network
structure, across the contact-topology subsystem's scenario matrix.

For each topology family x model (voter, SIS, SIRS) x window size:

  * mean wave parallelism (tasks / waves) and conflict density from
    ``window_schedule_stats`` — how much concurrency the record check
    exposes on that graph;
  * scheduling overhead: median wall time of the jitted conflict-matrix +
    wave-level pass (the protocol's O(W^2) term) per window.

Emits BENCH_topology.json next to this file (or --out PATH):

  {"meta": {...}, "rows": [{"model", "topology", "window", "n_tasks",
   "n_waves", "mean_parallelism", "conflict_density", "sched_seconds",
   "max_degree", "n_edges"}, ...]}

Run:  PYTHONPATH=src python benchmarks/topology_sweep.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core.records import wave_levels, window_conflicts
from repro.core.wavefront import window_schedule_stats
from repro.mabs.sir import SIRConfig, SIRModel
from repro.mabs.sis import SISModel
from repro.mabs.voter import VoterModel
from repro.topology import (
    barabasi_albert,
    connect_isolated,
    erdos_renyi,
    lattice2d,
    ring,
    watts_strogatz,
)
from repro.utils.timing import median_time


def topologies(n: int, key):
    """The benchmark's graph family matrix (all on n nodes)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    side = int(n ** 0.5)
    assert side * side == n, "n must be a perfect square for the lattice"
    return {
        "ring_k4": ring(n, 4),
        "lattice_vn": lattice2d(side, side, neighborhood="von_neumann"),
        "lattice_moore": lattice2d(side, side, neighborhood="moore"),
        "watts_strogatz": watts_strogatz(n, 4, 0.1, k1),
        # low-p ER leaves isolated nodes, which voter/Axelrod reject
        "erdos_renyi": connect_isolated(erdos_renyi(n, 4.0 / n, k2), k4),
        "barabasi_albert": barabasi_albert(n, 2, k3),
    }


def models_for(topo, n: int):
    sir_cfg = SIRConfig(n_agents=n, k=4, subset_size=max(4, n // 64))
    return {
        "voter": VoterModel(topo),
        "sis": SISModel(topo),
        "sirs": SIRModel(sir_cfg, topology=topo),
    }


def bench_one(model, window: int, *, strict: bool = True, seed: int = 0):
    recipes = model.create_tasks(jax.random.key(seed), 0, window)
    valid = jnp.ones((window,), dtype=bool)
    stats = window_schedule_stats(model, recipes, valid, strict=strict)

    @jax.jit
    def schedule(recipes, valid):
        conf = window_conflicts(model, recipes, valid, strict=strict)
        return wave_levels(conf, valid)

    sched_s = median_time(lambda: schedule(recipes, valid),
                          repeats=5, warmup=2)
    return {
        "n_tasks": stats["n_tasks"],
        "n_waves": stats["n_waves"],
        "mean_parallelism": stats["mean_parallelism"],
        "conflict_density": stats["conflict_density"],
        "sched_seconds": float(sched_s),
    }


def run(n: int, windows, *, seed: int = 0):
    rows = []
    topos = topologies(n, jax.random.key(seed))
    for tname, topo in topos.items():
        for mname, model in models_for(topo, n).items():
            for w in windows:
                r = bench_one(model, w, seed=seed)
                r.update({
                    "model": mname,
                    "topology": tname,
                    "window": int(w),
                    "max_degree": int(topo.max_degree),
                    "n_edges": int(topo.n_edges),
                })
                rows.append(r)
                print(f"{mname:6s} {tname:16s} W={w:5d} "
                      f"waves={r['n_waves']:4d} "
                      f"par={r['mean_parallelism']:7.2f} "
                      f"sched={r['sched_seconds']*1e3:7.2f}ms")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="nodes (square)")
    ap.add_argument("--windows", type=int, nargs="+",
                    default=[64, 256, 1024])
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_topology.json"))
    args = ap.parse_args()
    n, windows = args.n, args.windows
    if args.quick:
        n, windows = 256, [64, 256]

    rows = run(n, windows)
    payload = {
        "meta": {
            "n_nodes": n,
            "windows": [int(w) for w in windows],
            "backend": jax.default_backend(),
            "strict": True,
        },
        "rows": rows,
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
