"""Topology sweep: wave parallelism and scheduling overhead vs network
structure, across the contact-topology subsystem's scenario matrix.

For each topology family x model (voter, SIS, SIRS) x window size:

  * mean wave parallelism (tasks / waves) and conflict density from
    ``window_schedule_stats`` — how much concurrency the record check
    exposes on that graph;
  * scheduling overhead: median wall time of the jitted conflict-matrix +
    wave-level pass (the protocol's O(W^2) term) per window.

A second section benchmarks the sparse edge-list *builders* at large N
(``--build-ns``, default 10^5 and 10^6): wall time to construct each
random family plus one SIS window scheduled on the built Watts-Strogatz
graph — the end-to-end evidence that 10^6-node networks construct and
schedule on CPU without any [n, n] allocation.

Emits BENCH_topology.json next to this file (or --out PATH):

  {"meta": {...}, "rows": [
    {"kind": "schedule", "model", "topology", "window", "n_tasks",
     "n_waves", "mean_parallelism", "conflict_density", "sched_seconds",
     "max_degree", "n_edges"},
    {"kind": "build", "topology", "n_nodes", "build_seconds", "n_edges",
     "max_degree", "sched_seconds"?}, ...]}

Run:  PYTHONPATH=src python benchmarks/topology_sweep.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro.core.records import wave_levels, window_conflicts
from repro.core.wavefront import window_schedule_stats
from repro.mabs.sir import SIRConfig, SIRModel
from repro.mabs.sis import SISModel
from repro.mabs.voter import VoterModel
from repro.topology import (
    barabasi_albert,
    connect_isolated,
    erdos_renyi,
    lattice2d,
    ring,
    watts_strogatz,
)
from repro.utils.timing import median_time


def topologies(n: int, key):
    """The benchmark's graph family matrix (all on n nodes)."""
    k1, k2, k3, k4 = jax.random.split(key, 4)
    side = int(n ** 0.5)
    assert side * side == n, "n must be a perfect square for the lattice"
    return {
        "ring_k4": ring(n, 4),
        "lattice_vn": lattice2d(side, side, neighborhood="von_neumann"),
        "lattice_moore": lattice2d(side, side, neighborhood="moore"),
        "watts_strogatz": watts_strogatz(n, 4, 0.1, k1),
        # low-p ER leaves isolated nodes, which voter/Axelrod reject
        "erdos_renyi": connect_isolated(erdos_renyi(n, 4.0 / n, k2), k4),
        "barabasi_albert": barabasi_albert(n, 2, k3),
    }


def models_for(topo, n: int):
    sir_cfg = SIRConfig(n_agents=n, k=4, subset_size=max(4, n // 64))
    return {
        "voter": VoterModel(topo),
        "sis": SISModel(topo),
        "sirs": SIRModel(sir_cfg, topology=topo),
    }


def bench_one(model, window: int, *, strict: bool = True, seed: int = 0):
    recipes = model.create_tasks(jax.random.key(seed), 0, window)
    valid = jnp.ones((window,), dtype=bool)
    stats = window_schedule_stats(model, recipes, valid, strict=strict)

    @jax.jit
    def schedule(recipes, valid):
        conf = window_conflicts(model, recipes, valid, strict=strict)
        return wave_levels(conf, valid)

    sched_s = median_time(lambda: schedule(recipes, valid),
                          repeats=5, warmup=2)
    return {
        "n_tasks": stats["n_tasks"],
        "n_waves": stats["n_waves"],
        "mean_parallelism": stats["mean_parallelism"],
        "conflict_density": stats["conflict_density"],
        "sched_seconds": float(sched_s),
    }


def run(n: int, windows, *, seed: int = 0):
    rows = []
    topos = topologies(n, jax.random.key(seed))
    for tname, topo in topos.items():
        for mname, model in models_for(topo, n).items():
            for w in windows:
                r = bench_one(model, w, seed=seed)
                r.update({
                    "kind": "schedule",
                    "model": mname,
                    "topology": tname,
                    "window": int(w),
                    "max_degree": int(topo.max_degree),
                    "n_edges": int(topo.n_edges),
                })
                rows.append(r)
                print(f"{mname:6s} {tname:16s} W={w:5d} "
                      f"waves={r['n_waves']:4d} "
                      f"par={r['mean_parallelism']:7.2f} "
                      f"sched={r['sched_seconds']*1e3:7.2f}ms")
    return rows


def run_builds(build_ns, *, window: int = 256, seed: int = 0):
    """Sparse-builder scaling rows: construction wall time per family at
    each n, plus one SIS window scheduled on the built Watts-Strogatz
    graph (the large-N scheduling smoke, in the artifact)."""
    import time

    rows = []
    for n in build_ns:
        key = jax.random.key(seed)
        side = int(round(n ** 0.5))
        builders = {
            "ring_k4": lambda: ring(n, 4),
            "lattice_vn": lambda: lattice2d(side, n // side),
            "watts_strogatz": lambda: watts_strogatz(n, 4, 0.1, key),
            "erdos_renyi": lambda: erdos_renyi(n, 4.0 / n, key),
            "barabasi_albert": lambda: barabasi_albert(n, 2, key),
            # chunked attachment fast path: degrees frozen per block of
            # 4096 arrivals (after an equally-sized exact warm-up), so
            # the attachment scan is n/4096 vectorized steps instead of
            # n sequential ones — the ROADMAP's BA-build bottleneck fix
            "barabasi_albert_chunked": lambda: barabasi_albert(
                n, 2, key, chunk=4096),
        }
        for tname, build in builders.items():
            t0 = time.perf_counter()
            topo = build()
            topo.neighbors.block_until_ready()
            dt = time.perf_counter() - t0
            row = {
                "kind": "build",
                "topology": tname,
                "n_nodes": int(topo.n_nodes),
                "build_seconds": float(dt),
                "n_edges": int(topo.n_edges),
                "max_degree": int(topo.max_degree),
            }
            if tname == "watts_strogatz":
                # bounded-degree graph: one scheduled SIS window on top
                row.update(bench_one(SISModel(topo), window, seed=seed))
                row["kind"] = "build"
                row["window"] = int(window)
            rows.append(row)
            sched = (f" sched={row['sched_seconds']*1e3:7.2f}ms"
                     if "sched_seconds" in row else "")
            print(f"build  {tname:16s} n={n:8d} "
                  f"{dt:7.2f}s edges={row['n_edges']:9d}{sched}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024, help="nodes (square)")
    ap.add_argument("--windows", type=int, nargs="+",
                    default=[64, 256, 1024])
    ap.add_argument("--build-ns", type=int, nargs="*",
                    default=[100_000, 1_000_000],
                    help="builder-scaling sizes (empty to skip)")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_topology.json"))
    args = ap.parse_args()
    n, windows, build_ns = args.n, args.windows, args.build_ns
    if args.quick:
        n, windows, build_ns = 256, [64, 256], [10_000]

    rows = run(n, windows)
    rows.extend(run_builds(build_ns))
    from repro.obs import provenance

    payload = {
        "meta": {
            # environment header — rendered by report.py mabs
            "provenance": provenance(),
            "n_nodes": n,
            "windows": [int(w) for w in windows],
            "build_ns": [int(b) for b in build_ns],
            "backend": jax.default_backend(),
            "strict": True,
        },
        "rows": rows,
    }
    out = os.path.abspath(args.out)
    with open(out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
