"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.api import build_model

ALL_ARCHS = sorted(ARCHS)


def _batch(cfg, b=2, t=32):
    batch = {"tokens": jnp.ones((b, t), jnp.int32),
             "labels": jax.random.randint(jax.random.key(1), (b, t), 0,
                                          cfg.vocab)}
    if cfg.frontend == "vision_stub":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(2), (b, 8, cfg.d_model))
    if cfg.is_encdec:
        batch["src_embeds"] = 0.1 * jax.random.normal(
            jax.random.key(3), (b, t, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg)
    logits, _ = jax.jit(model.apply_train)(params, batch)
    assert logits.shape == (2, batch["tokens"].shape[1], cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_grads_finite(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, b=1, t=16)
    (loss, _), grads = jax.jit(jax.value_and_grad(
        model.loss, has_aux=True))(params, batch)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in leaves)
    assert gn > 0.0


@pytest.mark.parametrize("arch", ["smollm-360m", "h2o-danube-3-4b",
                                  "qwen1.5-32b", "deepseek-7b", "rwkv6-3b",
                                  "hymba-1.5b", "arctic-480b",
                                  "qwen3-moe-235b-a22b",
                                  "seamless-m4t-medium", "internvl2-76b"])
def test_prefill_decode_consistent_with_train(arch):
    """Serving path must match teacher-forced logits position by position."""
    cfg = ARCHS[arch].reduced()
    if cfg.moe is not None:
        # dropless capacity: capacity-overflow drops depend on the token
        # count, which differs between the teacher-forced and decode paths;
        # the equivalence check requires no drops on either side.
        import dataclasses

        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    b, t = 1, 12
    toks = jax.random.randint(jax.random.key(5), (b, t), 0, cfg.vocab)
    batch = {"tokens": toks}
    tb = {"tokens": toks, "labels": toks}
    if cfg.is_encdec:
        se = 0.1 * jax.random.normal(jax.random.key(6), (b, t, cfg.d_model))
        batch["src_embeds"] = se
        tb["src_embeds"] = se
    if cfg.frontend == "vision_stub":
        pe = 0.1 * jax.random.normal(jax.random.key(7), (b, 8, cfg.d_model))
        batch["patch_embeds"] = pe
        tb["patch_embeds"] = pe
    lt, _ = jax.jit(model.apply_train)(params, tb)
    bp = dict(batch)
    bp["tokens"] = toks[:, :t - 1]
    states = model.init_states(b, max_len=t + 16)
    lp, states = jax.jit(model.prefill)(params, bp, states)
    ld, states = jax.jit(model.decode_step)(
        params, toks[:, t - 1:t], states)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(lt[:, t - 2]),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lt[:, t - 1]),
                               atol=2e-4)


def test_sliding_window_ring_cache_long_decode():
    """Decode far past the window: ring cache must equal a full cache
    because SWA masks out everything older than the window anyway."""
    cfg = ARCHS["h2o-danube-3-4b"].reduced()  # window 64 after reduction
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(4), (1, 8), 0, cfg.vocab)

    def rollout(max_len):
        states = model.init_states(1, max_len=max_len)
        lp, states = jax.jit(model.prefill)(
            params, {"tokens": toks}, states)
        out = [int(jnp.argmax(lp[0]))]
        for _ in range(90):  # well past window=64
            ld, states = jax.jit(model.decode_step)(
                params, jnp.asarray([[out[-1]]], jnp.int32), states)
            out.append(int(jnp.argmax(ld[0])))
        return out

    ring = rollout(max_len=cfg.sliding_window)      # ring wraps
    full = rollout(max_len=512)                     # never wraps
    assert ring == full


def test_param_count_analytic_close_to_actual():
    from repro.utils.pytree import tree_param_count

    for arch in ["smollm-360m", "deepseek-7b"]:
        cfg = ARCHS[arch]
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.key(0))
        actual = tree_param_count(shapes)
        analytic = cfg.n_params()
        assert abs(actual - analytic) / actual < 0.02, (arch, actual,
                                                        analytic)


def test_fp8_kv_cache_bounded_perturbation():
    """fp8(e4m3) KV cache: teacher-forced decode logits stay within the
    expected quantization noise (~e4m3 mantissa resolution, rmse <~7% of
    logit std on a random-init model; trained models tolerate this —
    standard KV-quantization practice). Halves decode cache memory."""
    cfg = ARCHS["h2o-danube-3-4b"].reduced()
    toks = jax.random.randint(jax.random.key(4), (1, 24), 0, cfg.vocab)
    forced = jax.random.randint(jax.random.key(9), (8,), 0, cfg.vocab)

    def rollout(c):
        m = build_model(c)
        params = m.init(jax.random.key(0))
        states = m.init_states(1, max_len=64)
        lp, states = jax.jit(m.prefill)(params, {"tokens": toks}, states)
        logits = [lp]
        for t in forced:
            ld, states = jax.jit(m.decode_step)(
                params, jnp.asarray([[t]], jnp.int32), states)
            logits.append(ld)
        return jnp.stack(logits)

    a = rollout(cfg)
    b = rollout(cfg.replace(kv_cache_dtype="float8_e4m3fn"))
    scale = float(jnp.std(a))
    rmse = float(jnp.sqrt(jnp.mean((a - b) ** 2))) / scale
    assert rmse < 0.12, rmse
    # and the cache is actually fp8
    m = build_model(cfg.replace(kv_cache_dtype="float8_e4m3fn"))
    st = m.init_states(1, max_len=32)
    assert st["segs"][0]["kv"].k.dtype == jnp.float8_e4m3fn
