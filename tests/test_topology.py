"""Topology subsystem: generator invariants, block aggregation, jit-safety."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.topology import (
    PAD,
    Topology,
    barabasi_albert,
    complete,
    erdos_renyi,
    from_adjacency,
    lattice2d,
    ring,
    watts_strogatz,
)

KEY = jax.random.key(42)


def _cases():
    return [
        ("ring", ring(30, 6)),
        ("lattice_vn", lattice2d(5, 6)),
        ("lattice_moore", lattice2d(5, 6, neighborhood="moore")),
        ("lattice_open", lattice2d(4, 5, periodic=False)),
        ("watts_strogatz", watts_strogatz(30, 4, 0.3, KEY)),
        ("erdos_renyi", erdos_renyi(30, 0.15, KEY)),
        ("barabasi_albert", barabasi_albert(30, 2, KEY)),
        ("complete", complete(10)),
    ]


@pytest.mark.parametrize("name,topo", _cases())
def test_padded_csr_invariants(name, topo):
    """Simple undirected graph: -1 padding matches degrees, rows hold
    distinct non-self neighbors, adjacency is symmetric."""
    nb = np.asarray(topo.neighbors)
    dg = np.asarray(topo.degrees)
    n = topo.n_nodes
    assert nb.dtype == np.int32 and dg.dtype == np.int32
    for v in range(n):
        row, d = nb[v], dg[v]
        assert (row[:d] >= 0).all() and (row[:d] < n).all()
        assert (row[d:] == PAD).all()
        assert len(set(row[:d].tolist())) == d, "duplicate neighbor"
        assert v not in row[:d], "self loop"
    adj = np.asarray(topo.adjacency())
    assert (adj == adj.T).all()
    assert (adj.sum(1) == dg).all()


def test_ring_structure():
    t = ring(10, 4)
    nb = np.asarray(t.neighbors)
    assert (np.asarray(t.degrees) == 4).all()
    assert sorted(nb[0].tolist()) == sorted([1, 2, 8, 9])


@pytest.mark.parametrize("neighborhood,deg", [("von_neumann", 4),
                                              ("moore", 8)])
def test_lattice_degrees(neighborhood, deg):
    t = lattice2d(6, 6, neighborhood=neighborhood)
    assert (np.asarray(t.degrees) == deg).all()
    # interior node of an open lattice keeps full degree; corner does not
    t_open = lattice2d(6, 6, neighborhood=neighborhood, periodic=False)
    dg = np.asarray(t_open.degrees).reshape(6, 6)
    assert dg[3, 3] == deg
    assert dg[0, 0] < deg


def test_watts_strogatz_limits():
    # beta=0 is exactly the ring
    t0 = watts_strogatz(24, 4, 0.0, KEY)
    assert bool(jnp.all(t0.adjacency() == ring(24, 4).adjacency()))
    # beta=1 keeps edge count <= ring's (dedup) but rewires most edges
    t1 = watts_strogatz(200, 4, 1.0, KEY)
    same = int(jnp.sum(t1.adjacency() & ring(200, 4).adjacency())) // 2
    assert same < 100  # far fewer than the ring's 400 edges survive


def test_erdos_renyi_edge_count():
    n, p = 200, 0.05
    t = erdos_renyi(n, p, KEY)
    expect = p * n * (n - 1) / 2
    assert 0.7 * expect < int(t.n_edges) < 1.3 * expect


def test_barabasi_albert_structure():
    n, m = 100, 3
    t = barabasi_albert(n, m, KEY)
    dg = np.asarray(t.degrees)
    seed_sz = m + 1
    # every arriving node contributes exactly m edges
    assert int(t.n_edges) == seed_sz * (seed_sz - 1) // 2 + (n - seed_sz) * m
    assert dg.min() >= m
    # heavy tail: the hub clearly exceeds the minimum degree
    assert dg.max() >= 2 * m


def test_from_adjacency_roundtrip():
    rng = np.random.RandomState(0)
    adj = np.triu(rng.rand(20, 20) < 0.2, k=1)
    adj = adj | adj.T
    t = from_adjacency(jnp.asarray(adj))
    assert (np.asarray(t.adjacency()) == adj).all()


def test_generator_jit_and_pytree():
    """Random generators are jittable with a static max_degree, and
    Topology traverses as a pytree."""
    f = jax.jit(lambda k: erdos_renyi(32, 0.2, k, max_degree=32))
    t = f(jax.random.key(3))
    assert isinstance(t, Topology)
    assert len(jax.tree_util.tree_leaves(t)) == 2
    ref = erdos_renyi(32, 0.2, jax.random.key(3), max_degree=32)
    assert bool(jnp.all(t.neighbors == ref.neighbors))


def test_gather_and_neighbor_fraction():
    t = ring(12, 4)
    vals = jnp.arange(12, dtype=jnp.float32)
    got, mask = t.gather(vals, jnp.asarray([0]))
    assert bool(jnp.all(mask))
    assert sorted(np.asarray(got)[0].tolist()) == [1.0, 2.0, 10.0, 11.0]
    ind = jnp.arange(12) % 2 == 0  # even nodes
    frac = t.neighbor_fraction(ind, jnp.arange(12))
    # ring-4 neighborhood {v±1, v±2} always holds exactly two even nodes
    assert bool(jnp.all(frac == 0.5))


def test_block_graph_matches_ring_formula():
    """Aggregate subset graph of a ring == circular block-distance rule —
    the paper's §4.2 adjacency, now derived instead of hard-wired."""
    n, k, s = 120, 14, 10
    t = ring(n, k)
    bg = t.block_graph(s)
    adj = np.asarray(bg.adjacency())
    m, reach = n // s, -(-(k // 2) // s)
    for b1 in range(m):
        for b2 in range(m):
            d = abs(b1 - b2)
            assert adj[b1, b2] == (min(d, m - d) <= reach)


def test_sample_neighbor_uniform_support():
    t = ring(9, 4)
    picks = {int(t.sample_neighbor(jax.random.key(i), jnp.int32(4)))
             for i in range(64)}
    assert picks == {2, 3, 5, 6}


def test_connect_isolated():
    from repro.topology import connect_isolated, erdos_renyi

    t = erdos_renyi(200, 0.008, KEY)  # low p: isolated nodes near-certain
    assert int(t.degrees.min()) == 0
    fixed = connect_isolated(t, jax.random.key(1))
    assert int(fixed.degrees.min()) >= 1
    # existing edges untouched
    assert bool(jnp.all(~t.adjacency() | fixed.adjacency()))
