"""Observability subsystem tests (repro.obs).

Pins the three contracts docs/observability.md promises:

  * tracing is OFF by default — no tracer installed, no events recorded,
    and engine stats come back as host-native Python scalars either way;
  * tracing ON does not perturb the protocol — every registry engine
    stays bit-exact vs the sequential oracle with a tracer installed,
    and the export passes the Chrome trace-event schema validator
    (matched B/E spans, monotone timestamps, known phases);
  * the stats registry is the single schema authority — undeclared keys
    are rejected at the ``finalize_stats`` boundary, declared ones are
    normalized to their declared host types.

The 8-device sharded lane reuses the subprocess pattern of
test_engine_differential.py (the main process keeps its default single
device); it drives benchmarks/trace_smoke.py — the same script the CI
trace-export smoke step runs.
"""
import json
import os
import subprocess
import sys

import jax
import pytest

from conftest import BASE_SEED

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _voter(n=48, k=4):
    from repro.mabs.voter import VoterModel
    from repro.topology import ring

    return VoterModel(ring(n, k))


# --------------------------------------------------------------------------
# stats registry


def test_stats_registry_declarations():
    from repro.obs import STATS_VERSION, registry, row_keys
    from repro.obs.stats import GROUPS

    reg = registry()
    assert isinstance(STATS_VERSION, int) and STATS_VERSION >= 1
    assert reg, "registry must not be empty"
    for key, spec in reg.items():
        assert spec.key == key
        assert spec.group in GROUPS
        assert spec.kind in ("int", "float", "bool", "mapping")
        assert spec.description
    # the core quartet every engine emits
    for key in ("total_tasks", "n_windows", "total_waves",
                "mean_parallelism"):
        assert key in reg and not reg[key].nullable
    # row_keys: declaration order, group-filtered, all-groups default
    assert set(row_keys("comm")) == {k for k, s in reg.items()
                                     if s.group == "comm"}
    assert row_keys() == tuple(reg)
    both = row_keys("comm", "overlap")
    assert "per_wave_comm_bytes" in both and "mean_overlap_depth" in both
    assert "total_tasks" not in both


def test_finalize_stats_normalizes_and_rejects():
    import numpy as np

    from repro.obs import finalize_stats

    out = finalize_stats({
        "total_tasks": np.int64(7),
        "mean_parallelism": np.float32(1.5),
        "halo": np.bool_(True),
        "comm_modes": {"split": np.int32(3)},
        "per_wave_split_rows": None,       # nullable
    })
    assert out["total_tasks"] == 7 and type(out["total_tasks"]) is int
    assert out["mean_parallelism"] == 1.5
    assert type(out["mean_parallelism"]) is float
    assert out["halo"] is True
    assert out["comm_modes"] == {"split": 3}
    assert type(out["comm_modes"]["split"]) is int
    assert out["per_wave_split_rows"] is None
    with pytest.raises(ValueError, match="undeclared"):
        finalize_stats({"no_such_stat": 1})
    # non-strict: unknown keys pass through (ad-hoc analysis dicts)
    assert finalize_stats({"no_such_stat": 1}, strict=False) == {
        "no_such_stat": 1}
    with pytest.raises(ValueError, match="not nullable"):
        finalize_stats({"total_tasks": None})


def test_engine_stats_are_host_native():
    """Every engine's run stats pass the registry boundary as Python
    scalars — no 0-d arrays or numpy types leak to callers."""
    from repro.engine import make_engine

    m = _voter()
    st0 = m.init_state(jax.random.key(BASE_SEED + 1))
    for ename in ("sequential", "wavefront", "wavefront_overlap"):
        _, stats = make_engine(ename, m, window=16).run(
            st0, 40, seed=BASE_SEED + 2)
        for k, v in stats.items():
            assert v is None or type(v) in (int, float, bool, dict), (
                f"{ename}: stat {k!r} leaked {type(v).__name__}")


# --------------------------------------------------------------------------
# tracer core


def test_tracing_off_by_default():
    from repro.obs import current_tracer, tracing

    assert current_tracer() is None
    with tracing() as tr:
        assert current_tracer() is tr
        with tracing() as inner:     # blocks nest, inner wins
            assert current_tracer() is inner
        assert current_tracer() is tr
    assert current_tracer() is None


def test_span_tracer_subdivide_and_export(tmp_path):
    from repro.obs import SpanTracer, validate_chrome_trace

    tr = SpanTracer()
    with tr.span("run", engine="test") as run:
        with tr.span("execute", index=0) as sp:
            pass
        sp.args["n_waves"] = 2          # args mutable after exit
        slots = tr.subdivide(sp, "wave", [3, 1],
                             [{"level": 0}, {"level": 1}])
    assert run.t1 is not None
    assert len(slots) == 2
    # width-proportional attribution covers the parent span exactly
    assert slots[0][0] == pytest.approx(sp.t0)
    assert slots[0][1] == pytest.approx(3 * slots[1][1])
    assert slots[1][0] + slots[1][1] == pytest.approx(sp.t1)
    path = tmp_path / "t.json"
    payload = tr.export(str(path))
    assert validate_chrome_trace(payload) == len(payload["traceEvents"])
    on_disk = json.loads(path.read_text())
    assert validate_chrome_trace(on_disk)
    waves = [e for e in on_disk["traceEvents"] if e["name"] == "wave"]
    assert [w["args"]["level"] for w in waves] == [0, 1]
    assert all(w["args"]["attributed"] for w in waves)
    execs = [e for e in on_disk["traceEvents"]
             if e["name"] == "execute" and e["ph"] == "B"]
    assert execs[0]["args"]["n_waves"] == 2


def test_validator_rejects_malformed():
    from repro.obs import validate_chrome_trace

    ok = {"name": "a", "ph": "B", "ts": 1.0, "pid": 1, "tid": 0}
    end = {"name": "a", "ph": "E", "ts": 2.0, "pid": 1, "tid": 0}
    assert validate_chrome_trace([ok, end]) == 2
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({})
    with pytest.raises(ValueError, match="missing"):
        validate_chrome_trace([{"ph": "B", "ts": 0, "pid": 1, "tid": 0}])
    with pytest.raises(ValueError, match="unknown.*phase"):
        validate_chrome_trace([dict(ok, ph="Q")])
    with pytest.raises(ValueError, match="bad ts"):
        validate_chrome_trace([dict(ok, ts=-1.0)])
    with pytest.raises(ValueError, match="bad.*dur"):
        validate_chrome_trace([dict(ok, ph="X")])
    with pytest.raises(ValueError, match="unclosed"):
        validate_chrome_trace([ok])
    with pytest.raises(ValueError, match="without open B"):
        validate_chrome_trace([end])
    with pytest.raises(ValueError, match="cross-nested"):
        validate_chrome_trace([
            ok, {"name": "b", "ph": "B", "ts": 1.5, "pid": 1, "tid": 0},
            end, {"name": "b", "ph": "E", "ts": 2.5, "pid": 1, "tid": 0}])


# --------------------------------------------------------------------------
# traced engines: bit-exactness + taxonomy (single device in-process)


@pytest.mark.parametrize("ename", ["sequential", "wavefront",
                                   "wavefront_overlap"])
def test_traced_run_bit_exact_and_valid(ename):
    import jax.numpy as jnp

    from repro.core import ProtocolConfig, run_oracle
    from repro.engine import make_engine
    from repro.obs import tracing, validate_chrome_trace

    m = _voter()
    st0 = m.init_state(jax.random.key(BASE_SEED + 1))
    cfg = ProtocolConfig(window=16, strict=True)
    oracle = run_oracle(m, st0, 40, seed=BASE_SEED + 2, config=cfg)
    eng = make_engine(ename, m, window=16, strict=True)
    plain_out, plain_stats = eng.run(st0, 40, seed=BASE_SEED + 2)
    with tracing() as tr:
        out, stats = eng.run(st0, 40, seed=BASE_SEED + 2)
    for a, b in zip(jax.tree_util.tree_leaves(out),
                    jax.tree_util.tree_leaves(oracle)):
        assert bool(jnp.all(a == b)), f"{ename} diverged under tracing"
    assert stats == plain_stats, f"{ename}: tracing changed the stats"
    payload = tr.export()
    validate_chrome_trace(payload)
    names = {e["name"] for e in payload["traceEvents"]}
    assert {"run", "execute"} <= names
    if ename != "sequential":
        assert {"schedule", "wave"} <= names
    if ename.endswith("_overlap"):
        assert "boundary" in names
    # untraced runs record nothing: the tracer we never installed for
    # plain_out doesn't exist; a fresh run outside tracing() adds no
    # events to the old tracer either
    n = len(tr.events())
    eng.run(st0, 40, seed=BASE_SEED + 2)
    assert len(tr.events()) == n


def test_trace_wave_widths_match_schedule():
    """Wave spans carry the schedule's real widths: they sum to the
    task total, and each window's widths sum to its task count."""
    from repro.engine import make_engine
    from repro.obs import tracing

    m = _voter()
    st0 = m.init_state(jax.random.key(BASE_SEED + 1))
    eng = make_engine("wavefront", m, window=16)
    with tracing() as tr:
        _, stats = eng.run(st0, 40, seed=BASE_SEED + 2)
    waves = [e for e in tr.events() if e["name"] == "wave"]
    assert len(waves) == stats["total_waves"]
    assert sum(e["args"]["width"] for e in waves) == stats["total_tasks"]


# --------------------------------------------------------------------------
# 8-device sharded lane: the CI smoke script, bit-exactness included


def run_py(argv, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, *argv], capture_output=True,
                       text=True, timeout=timeout, env=env, cwd=REPO)
    assert p.returncode == 0, p.stderr[-4000:]
    return p.stdout


def test_trace_smoke_sharded_8dev(tmp_path):
    """The CI trace-export path end to end: traced sharded-overlap run,
    bit-exact assert, schema-valid export with comm spans, and both
    report subcommands rendering from the artifact."""
    trace = tmp_path / "trace.json"
    out = run_py([os.path.join(REPO, "benchmarks", "trace_smoke.py"),
                  "--out", str(trace)])
    assert "TRACE-OK" in out
    payload = json.loads(trace.read_text())
    from repro.obs import validate_chrome_trace

    validate_chrome_trace(payload)
    gathers = [e for e in payload["traceEvents"]
               if e["name"] == "halo_gather"]
    assert gathers, "sharded trace must carry halo_gather spans"
    for e in gathers:
        assert e["args"]["rung"] in ("split", "window_halo", "pair_halo",
                                     "full_state")
        assert e["args"]["rows"] > 0
        assert e["args"]["bytes"] >= e["args"]["rows"]
    owned = [e["args"]["owned"] for e in payload["traceEvents"]
             if e["name"] == "wave" and "owned" in e["args"]]
    assert owned and all(len(o) == 8 for o in owned), (
        "wave spans must carry 8 per-device owned-task counts")
    explain = run_py(["-m", "benchmarks.report", "explain", str(trace)])
    assert "Wave-size histogram" in explain
    assert "Comm ledger" in explain
    assert "Per-device load (8 devices" in explain
    timing = run_py(["-m", "benchmarks.report", "trace", str(trace)])
    assert "Per-window split" in timing


# --------------------------------------------------------------------------
# satellites: timing fence, provenance


def test_block_all_fences_every_leaf():
    import jax.numpy as jnp

    from repro.utils.timing import block_all, median_time

    out = {"a": jnp.ones((4,)), "b": (jnp.zeros((2, 2)), 3, None)}
    assert block_all(out) is out          # passthrough, non-arrays ok
    t = median_time(lambda: {"x": jnp.arange(8) * 2, "n": 1},
                    repeats=3, warmup=1)
    assert t >= 0.0


def test_provenance_header():
    from repro.obs import STATS_VERSION, provenance

    p = provenance()
    assert p["jax_version"] == str(jax.__version__)
    assert p["backend"] == jax.default_backend()
    assert isinstance(p["device_count"], int) and p["device_count"] >= 1
    assert isinstance(p["device_kind"], str)
    assert "T" in p["timestamp"]          # ISO-8601
    assert p["stats_version"] == STATS_VERSION
    assert p["git_sha"] is None or isinstance(p["git_sha"], str)
    json.dumps(p)                          # JSON-safe by construction
