"""Property-based tests of the scheduler's core invariants.

Seeded randomized sweeps (no external property-testing dependency: the
container has no ``hypothesis``; deterministic seeds keep failures
reproducible while covering the same input space).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProtocolConfig, run_oracle, run_wavefront, wave_levels
from repro.core.records import wave_levels_capped
from repro.kernels.conflict.ref import conflict_matrix_ref
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel


def _random_conflicts(seed):
    rng = np.random.RandomState(seed)
    n = rng.randint(4, 25)
    density = rng.rand() * 0.5
    return np.tril(rng.rand(n, n) < density, k=-1)


@pytest.mark.parametrize("seed", range(50))
def test_levels_topological(seed):
    conf = _random_conflicts(seed)
    n = conf.shape[0]
    lv = np.asarray(wave_levels(jnp.asarray(conf), jnp.ones(n, bool)))
    ii, jj = np.nonzero(conf)
    assert (lv[ii] > lv[jj]).all()
    # level k > 0 implies a conflicting predecessor at level k-1 (greedy
    # tightness: no task is scheduled later than necessary)
    for i in range(n):
        if lv[i] > 0:
            deps = np.nonzero(conf[i])[0]
            assert lv[deps].max() == lv[i] - 1


@pytest.mark.parametrize("seed", range(30))
def test_capped_levels_valid(seed):
    conf = _random_conflicts(seed)
    n_workers = 1 + seed % 5
    n = conf.shape[0]
    lv = wave_levels_capped(conf, np.ones(n, bool), n_workers)
    ii, jj = np.nonzero(conf)
    assert (lv[ii] > lv[jj]).all()
    assert np.bincount(lv).max() <= n_workers


@pytest.mark.parametrize("seed", range(15))
def test_axelrod_wavefront_bitexact(seed):
    """For arbitrary model sizes and task counts, wavefront == sequential."""
    rng = np.random.RandomState(1000 + seed)
    n_agents = rng.randint(8, 41)
    n_features = rng.randint(2, 7)
    n_tasks = rng.randint(10, 61)
    m = AxelrodModel(AxelrodConfig(n_agents=n_agents, n_features=n_features,
                                   q=3))
    st0 = m.init_state(jax.random.key(seed))
    cfg = ProtocolConfig(window=32, strict=True)
    w, _ = run_wavefront(m, st0, n_tasks, seed=seed, config=cfg)
    s = run_oracle(m, st0, n_tasks, seed=seed, config=cfg)
    assert bool(jnp.all(w["traits"] == s["traits"]))


@pytest.mark.parametrize("seed", range(20))
def test_conflict_kernel_matches_ref(seed):
    from repro.kernels.conflict.ops import conflict_matrix

    rng = np.random.RandomState(seed)
    n_ids = rng.randint(2, 25)
    w = 128
    reads = rng.randint(0, n_ids, size=(w, 2)).astype(np.int32)
    writes = reads[:, 1:].copy()
    valid = rng.rand(w) < 0.9
    for strict in (True, False):
        out = conflict_matrix(reads, writes, valid, strict=strict)
        ref = conflict_matrix_ref(jnp.asarray(reads), jnp.asarray(writes),
                                  jnp.asarray(valid), strict=strict)
        assert bool(jnp.all(out == ref))
