"""Property-based tests (hypothesis): the scheduler's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import ProtocolConfig, run_oracle, run_wavefront, wave_levels
from repro.core.records import wave_levels_capped
from repro.kernels.conflict.ref import conflict_matrix_ref
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel


@st.composite
def conflict_matrices(draw):
    n = draw(st.integers(4, 24))
    density = draw(st.floats(0.0, 0.5))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.RandomState(seed)
    conf = np.tril(rng.rand(n, n) < density, k=-1)
    return conf


@given(conflict_matrices())
@settings(max_examples=50, deadline=None)
def test_levels_topological(conf):
    n = conf.shape[0]
    lv = np.asarray(wave_levels(jnp.asarray(conf), jnp.ones(n, bool)))
    ii, jj = np.nonzero(conf)
    assert (lv[ii] > lv[jj]).all()
    # level k > 0 implies a conflicting predecessor at level k-1 (greedy
    # tightness: no task is scheduled later than necessary)
    for i in range(n):
        if lv[i] > 0:
            deps = np.nonzero(conf[i])[0]
            assert lv[deps].max() == lv[i] - 1


@given(conflict_matrices(), st.integers(1, 5))
@settings(max_examples=30, deadline=None)
def test_capped_levels_valid(conf, n_workers):
    n = conf.shape[0]
    lv = wave_levels_capped(conf, np.ones(n, bool), n_workers)
    ii, jj = np.nonzero(conf)
    assert (lv[ii] > lv[jj]).all()
    assert np.bincount(lv).max() <= n_workers


@given(st.integers(0, 2**16), st.integers(8, 40), st.integers(2, 6),
       st.integers(10, 60))
@settings(max_examples=15, deadline=None)
def test_axelrod_wavefront_bitexact(seed, n_agents, n_features, n_tasks):
    """For arbitrary model sizes and task counts, wavefront == sequential."""
    m = AxelrodModel(AxelrodConfig(n_agents=n_agents, n_features=n_features,
                                   q=3))
    st0 = m.init_state(jax.random.key(seed))
    cfg = ProtocolConfig(window=32, strict=True)
    w, _ = run_wavefront(m, st0, n_tasks, seed=seed, config=cfg)
    s = run_oracle(m, st0, n_tasks, seed=seed, config=cfg)
    assert bool(jnp.all(w["traits"] == s["traits"]))


@given(st.integers(0, 10_000), st.integers(2, 24))
@settings(max_examples=20, deadline=None)
def test_conflict_kernel_matches_ref(seed, n_ids):
    from repro.kernels.conflict.ops import conflict_matrix

    rng = np.random.RandomState(seed)
    w = 128
    reads = rng.randint(0, n_ids, size=(w, 2)).astype(np.int32)
    writes = reads[:, 1:].copy()
    valid = rng.rand(w) < 0.9
    for strict in (True, False):
        out = conflict_matrix(reads, writes, valid, strict=strict)
        ref = conflict_matrix_ref(jnp.asarray(reads), jnp.asarray(writes),
                                  jnp.asarray(valid), strict=strict)
        assert bool(jnp.all(out == ref))
