"""Cross-engine differential harness — the guard rail for overlapped
execution.

Overlapped cross-window execution is the easiest place to silently break
sequential semantics (a carry frontier that misses one hazard class
produces *plausible* wrong trajectories), so every engine in the
registry is pinned bit-exactly to the sequential oracle over the full
scenario matrix:

    model    ∈ {voter, SIS, Axelrod, SIRS}
  × topology ∈ {ring, lattice2d, Watts-Strogatz, Erdos-Renyi,
                Barabasi-Albert}
  × engine   ∈ {sequential, wavefront, wavefront_overlap, sharded,
                sharded_window_halo, sharded_replicated, sharded_overlap}
  × full / padded-partial windows,

under 8 virtual host devices (the sharded engines' acceptance mesh; the
subprocess pattern of test_engine_sharded.py keeps the main process on
its default single device). The sweep is *seeded* fuzz: every draw is
offset by ``MABS_TEST_SEED`` (conftest.BASE_SEED), and CI runs the suite
under two distinct base seeds — a schedule bug that only fires for
particular conflict draws fails one of the two lanes.

Overlap stats are additionally checked for the monotone envelope
(``conftest.assert_overlap_stats_monotone``): depths bounded by the
window, counters consistent, and — vs the matching barrier run — the
fused schedule never executes *more* waves.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from conftest import (
    BASE_SEED,
    assert_engine_matches_oracle,
    assert_overlap_stats_monotone,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: every array engine in the registry (sequential doubles as the oracle;
#: ``sharded`` runs the per-wave halo split, ``sharded_window_halo`` the
#: monolithic middle rung of the comm ladder)
ALL_ENGINES = ("sequential", "wavefront", "wavefront_overlap",
               "sharded", "sharded_window_halo", "sharded_replicated",
               "sharded_overlap")


def run_py(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    # src for the package, tests for the shared conftest helpers
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(REPO, "src"), os.path.join(REPO, "tests")])
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, p.stderr[-4000:]
    return p.stdout


# --------------------------------------------------------------------------
# helpers shared with the subprocess sweeps (kept importable: the inner
# scripts exec this module's source to avoid duplicating the matrix)

def topology_matrix(key):
    """The five topology families, sized for the harness (n small enough
    that the full matrix compiles in CI, n chosen so 8 devices need the
    padded shard path for most families)."""
    from repro.topology import (
        barabasi_albert,
        connect_isolated,
        erdos_renyi,
        lattice2d,
        ring,
        watts_strogatz,
    )

    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    return {
        "ring": ring(50, 4),
        "lattice2d": lattice2d(7, 7, neighborhood="von_neumann"),
        "watts_strogatz": connect_isolated(
            watts_strogatz(50, 4, 0.2, k1), k2),
        "erdos_renyi": connect_isolated(erdos_renyi(50, 0.1, k3), k4),
        "barabasi_albert": barabasi_albert(50, 2, k5),
    }


def make_model(name, topo):
    from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
    from repro.mabs.sir import SIRConfig, SIRModel
    from repro.mabs.sis import SISModel
    from repro.mabs.voter import VoterModel

    n = topo.n_nodes
    if name == "voter":
        return VoterModel(topo)
    if name == "sis":
        return SISModel(topo)
    if name == "axelrod":
        return AxelrodModel(AxelrodConfig(n_agents=n, n_features=3, q=3),
                            topology=topo)
    if name == "sirs":
        s = 7 if n % 7 == 0 else 10
        return SIRModel(SIRConfig(n_agents=n, k=4, subset_size=s),
                        topology=topo)
    raise ValueError(name)


def sweep_one_model(mname, *, window=16):
    """The differential sweep for one model: all topologies × all
    registry engines × full and padded-partial window totals, bit-exact
    vs the oracle, with the overlap-stat envelope on overlapped runs."""
    from repro.core import ProtocolConfig, run_oracle
    from repro.engine import make_engine

    cfg = ProtocolConfig(window=window, strict=True)
    topos = topology_matrix(jax.random.key(BASE_SEED + 11))
    for tname, topo in topos.items():
        model = make_model(mname, topo)
        st0 = model.init_state(jax.random.key(BASE_SEED + 1))
        # 2 full windows; ring additionally runs the full-windows-only
        # case — 44 = 2 full + 1 padded partial window of 12
        totals = (32, 44) if tname == "ring" else (44,)
        engines = {e: make_engine(e, model, window=window, strict=True)
                   for e in ALL_ENGINES}
        for total in totals:
            oracle = run_oracle(model, st0, total, seed=BASE_SEED + 2,
                                config=cfg)
            for ename, eng in engines.items():
                stats = assert_engine_matches_oracle(
                    model, st0, total, engine=eng, window=window,
                    seed=BASE_SEED + 2, oracle_state=oracle)
                if ename.endswith("_overlap"):
                    assert_overlap_stats_monotone(stats, window=window)
        print(f"{mname:8s} {tname:16s} OK", flush=True)


# --------------------------------------------------------------------------
# the acceptance matrix: one subprocess per model, 8 virtual devices

@pytest.mark.parametrize("model", ["voter", "sis", "axelrod", "sirs"])
def test_differential_matrix_8dev(model):
    src_path = os.path.abspath(__file__)
    out = run_py(f"""
        import jax
        assert jax.device_count() == 8, jax.device_count()
        src = open({src_path!r}).read()
        ns = {{"__name__": "differential_inner", "__file__": {src_path!r}}}
        exec(compile(src, {src_path!r}, "exec"), ns)
        ns["sweep_one_model"]({model!r})
        print("MATRIX-OK")
    """)
    assert "MATRIX-OK" in out


# --------------------------------------------------------------------------
# in-process checks (default single-device view)

def test_overlap_monotone_vs_barrier():
    """Overlap must merge waves, never add them — and actually overlap
    on a graph with independence to exploit."""
    from repro.core import ProtocolConfig, run_engine
    from repro.topology import watts_strogatz

    m = make_model("voter",
                   watts_strogatz(64, 4, 0.2, jax.random.key(BASE_SEED + 5)))
    st0 = m.init_state(jax.random.key(BASE_SEED + 1))
    cfg = ProtocolConfig(window=32, strict=True)
    _, barrier = run_engine(m, st0, 100, seed=BASE_SEED + 2, config=cfg,
                            engine="wavefront")
    stats = assert_engine_matches_oracle(
        m, st0, 100, engine="wavefront_overlap", window=32,
        seed=BASE_SEED + 2)
    assert_overlap_stats_monotone(stats, window=32, barrier_stats=barrier)
    assert stats["mean_overlap_depth"] > 0, (
        "sparse voter windows must overlap across the boundary")
    assert stats["overlap_tasks_early"] > 0


def test_overlap_seeded_fuzz_wavefront():
    """Seeded fuzz: random (seed, total) draws through the overlapped
    wavefront engine vs the oracle — totals hit full, partial and
    single-window cases."""
    import numpy as np

    from repro.topology import watts_strogatz

    rng = np.random.RandomState(BASE_SEED + 77)
    m = make_model("sis",
                   watts_strogatz(48, 4, 0.3, jax.random.key(BASE_SEED)))
    st0 = m.init_state(jax.random.key(BASE_SEED + 3))
    for _ in range(4):
        seed = int(rng.randint(1000))
        total = int(rng.randint(1, 80))
        stats = assert_engine_matches_oracle(
            m, st0, total, engine="wavefront_overlap", window=16, seed=seed)
        assert_overlap_stats_monotone(stats, window=16)


def test_overlap_nonstrict_layout_agreement():
    """Under the paper's non-strict record rule engines may diverge from
    the oracle, but the two overlapped engines run the identical fused
    schedule — sharding stays a pure layout transform of it."""
    from repro.core import ProtocolConfig, run_engine
    from repro.topology import watts_strogatz

    m = make_model("voter",
                   watts_strogatz(64, 4, 0.2, jax.random.key(BASE_SEED + 9)))
    st0 = m.init_state(jax.random.key(BASE_SEED + 4))
    cfg = ProtocolConfig(window=32, strict=False)
    ov, _ = run_engine(m, st0, 100, seed=BASE_SEED + 5, config=cfg,
                       engine="wavefront_overlap")
    sh, _ = run_engine(m, st0, 100, seed=BASE_SEED + 5, config=cfg,
                       engine="sharded_overlap")
    assert bool(jnp.all(ov["opinions"] == sh["opinions"]))


def test_overlap_predicate_only_model():
    """Models without footprints route the cross-window record check
    through the broadcast pairwise predicate (no conflict kernel) — the
    overlapped engine must stay bit-exact there too."""
    from repro.topology import ring

    class PredicateVoter(type(make_model("voter", ring(32, 4)))):
        def task_footprint(self, recipes):
            return None

        def conflicts(self, a, b, *, strict=True):
            c = (a["u"] == b["v"]) | (a["v"] == b["v"])
            if strict:
                c = c | (a["v"] == b["u"])
            return c

    m = PredicateVoter(ring(40, 4))
    st0 = m.init_state(jax.random.key(BASE_SEED + 6))
    stats = assert_engine_matches_oracle(
        m, st0, 70, engine="wavefront_overlap", window=24,
        seed=BASE_SEED + 7)
    assert_overlap_stats_monotone(stats, window=24)


def test_overlap_knob_routes_through_config():
    """ProtocolConfig.overlap flips any windowed engine; the barrier
    engines raise nothing and the sequential engine ignores it."""
    from repro.core import ProtocolConfig, run_engine
    from repro.topology import ring

    m = make_model("voter", ring(32, 4))
    st0 = m.init_state(jax.random.key(0))
    cfg = ProtocolConfig(window=16, overlap=True)
    _, stats = run_engine(m, st0, 48, seed=1, config=cfg, engine="wavefront")
    assert stats["overlap"] is True
    cfg_off = ProtocolConfig(window=16, overlap=False)
    _, stats = run_engine(m, st0, 48, seed=1, config=cfg_off,
                          engine="wavefront_overlap")
    assert stats["overlap"] is False
    # sequential accepts (and ignores) the knob
    _, stats = run_engine(m, st0, 48, seed=1, config=cfg, engine="sequential")
    assert stats["mean_parallelism"] == 1.0
