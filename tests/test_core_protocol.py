"""Core protocol: wavefront scheduling semantics + the paper-rule gap."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ProtocolConfig,
    prefix_conflicts,
    run_oracle,
    run_wavefront,
    wave_levels,
    wave_levels_capped,
)
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
from repro.mabs.sir import SIRConfig, SIRModel


def test_wave_levels_chain():
    # fully serial chain: levels must be 0..n-1
    n = 8
    conf = jnp.tril(jnp.ones((n, n), bool), k=-1)
    lv = wave_levels(conf, jnp.ones(n, bool))
    assert list(np.asarray(lv)) == list(range(n))


def test_wave_levels_independent():
    n = 8
    conf = jnp.zeros((n, n), bool)
    lv = wave_levels(conf, jnp.ones(n, bool))
    assert list(np.asarray(lv)) == [0] * n


def test_wave_levels_respect_dependencies():
    rng = np.random.RandomState(0)
    for _ in range(20):
        n = 32
        conf = np.tril(rng.rand(n, n) < 0.15, k=-1)
        lv = np.asarray(wave_levels(jnp.asarray(conf), jnp.ones(n, bool)))
        for i in range(n):
            for j in range(i):
                if conf[i, j]:
                    assert lv[i] > lv[j]


def test_wave_levels_capped_capacity():
    n = 16
    conf = np.zeros((n, n), bool)
    lv = wave_levels_capped(conf, np.ones(n, bool), n_workers=4)
    counts = np.bincount(lv)
    assert counts.max() <= 4
    assert lv.max() == 3  # 16 independent tasks, 4 per wave


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_axelrod_wavefront_equals_sequential(seed):
    m = AxelrodModel(AxelrodConfig(n_agents=50, n_features=4, q=3))
    st0 = m.init_state(jax.random.key(seed))
    cfg = ProtocolConfig(window=64, strict=True)
    st_w, _ = run_wavefront(m, st0, 400, seed=seed, config=cfg)
    st_s = run_oracle(m, st0, 400, seed=seed, config=cfg)
    assert bool(jnp.all(st_w["traits"] == st_s["traits"]))


def test_axelrod_paper_rule_diverges():
    """The record rule exactly as stated in the paper misses the
    anti-dependence tgt_i == src_j; with enough conflicts it must diverge
    from sequential execution (DESIGN.md §10 / §2)."""
    m = AxelrodModel(AxelrodConfig(n_agents=12, n_features=4, q=2))
    st0 = m.init_state(jax.random.key(1))
    diverged = False
    for seed in range(6):
        st_p, _ = run_wavefront(m, st0, 400, seed=seed,
                                config=ProtocolConfig(window=64,
                                                      strict=False))
        st_s = run_oracle(m, st0, 400, seed=seed,
                          config=ProtocolConfig(window=64))
        if not bool(jnp.all(st_p["traits"] == st_s["traits"])):
            diverged = True
            break
    assert diverged, "paper rule unexpectedly matched sequential on all seeds"


@pytest.mark.parametrize("subset_size", [5, 10])
def test_sir_wavefront_equals_sequential(subset_size):
    m = SIRModel(SIRConfig(n_agents=100, k=6, subset_size=subset_size,
                           i0=0.3))
    st0 = m.init_state(jax.random.key(2))
    tasks = m.cfg.tasks_per_step() * 5
    cfg = ProtocolConfig(window=40, strict=True)
    st_w, _ = run_wavefront(m, st0, tasks, seed=3, config=cfg)
    st_s = run_oracle(m, st0, tasks, seed=3, config=cfg)
    assert bool(jnp.all(st_w["states"] == st_s["states"]))
    assert bool(jnp.all(st_w["new_states"] == st_s["new_states"]))


def test_sir_states_valid():
    m = SIRModel(SIRConfig(n_agents=100, k=6, subset_size=10, i0=0.3))
    st0 = m.init_state(jax.random.key(2))
    st, _ = run_wavefront(m, st0, m.cfg.tasks_per_step() * 10, seed=0,
                          config=ProtocolConfig(window=40))
    s = np.asarray(st["states"])
    assert set(np.unique(s)).issubset({0, 1, 2})


def test_prefix_conflicts_masks_invalid():
    m = AxelrodModel(AxelrodConfig(n_agents=10, n_features=2))
    rec = m.create_tasks(jax.random.key(0), 0, 16)
    valid = jnp.arange(16) < 10
    conf = prefix_conflicts(m.conflicts, rec, valid)
    c = np.asarray(conf)
    assert not c[10:].any() and not c[:, 10:].any()
    assert not np.triu(c).any()


def test_sir_reference_step_matches_protocol():
    """The synchronous whole-system stepper equals one protocol step
    (2M tasks) through the wavefront engine, per-agent keys and all."""
    m = SIRModel(SIRConfig(n_agents=100, k=6, subset_size=10, i0=0.3))
    st0 = m.init_state(jax.random.key(2))
    seed = 5
    st = st0
    for step in range(3):
        st = m.reference_step(st, jax.random.key(seed), step)
    st_w, _ = run_wavefront(m, st0, m.cfg.tasks_per_step() * 3, seed=seed,
                            config=ProtocolConfig(window=40, strict=True))
    assert bool(jnp.all(st_w["states"] == st["states"]))
    assert bool(jnp.all(st_w["new_states"] == st["new_states"]))
