"""Multi-device sharded-engine tests — run in subprocesses with 8 host
devices (the main test process must keep the default 1-device view).

Acceptance sweep: ShardedEngine bit-exact vs the sequential oracle on
voter and SIS over ring / lattice / Watts-Strogatz topologies, for full
and partial windows, including an agent count the device count does not
divide (exercising the padded shard path).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, p.stderr[-4000:]
    return p.stdout


@pytest.mark.parametrize("model", ["voter", "sis"])
def test_sharded_bitexact_topology_sweep(model):
    out = run_py(f"""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8, jax.device_count()
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.mabs.sis import SISModel
        from repro.mabs.voter import VoterModel
        from repro.topology import lattice2d, ring, watts_strogatz

        make = {{"voter": VoterModel, "sis": SISModel}}["{model}"]
        cfg = ProtocolConfig(window=64, strict=True)
        topos = {{
            # n=100: 8 does not divide -> padded shard path
            "ring": ring(100, 4),
            "lattice": lattice2d(10, 10, neighborhood="von_neumann"),
            "watts_strogatz": watts_strogatz(128, 4, 0.1, jax.random.key(2)),
        }}
        for name, topo in topos.items():
            m = make(topo)
            st0 = m.init_state(jax.random.key(7))
            # 128 = two full windows; 150 adds a partial tail window
            for total in (128, 150):
                sh, stats = run_engine(m, st0, total, seed=3, config=cfg,
                                       engine="sharded")
                sq = run_oracle(m, st0, total, seed=3, config=cfg)
                leaf = next(iter(st0))
                assert stats["n_devices"] == 8
                assert bool(jnp.all(sh[leaf] == sq[leaf])), (name, total)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_bitexact_axelrod_and_sir():
    """Beyond the acceptance matrix: the ownership contract also covers
    Axelrod (per-feature writes) and SIRS (contiguous block writes over
    two state buffers)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
        from repro.mabs.sir import SIRConfig, SIRModel

        cfg = ProtocolConfig(window=64, strict=True)
        m = AxelrodModel(AxelrodConfig(n_agents=41, n_features=3, q=3))
        st0 = m.init_state(jax.random.key(0))
        sh, _ = run_engine(m, st0, 100, seed=1, config=cfg, engine="sharded")
        sq = run_oracle(m, st0, 100, seed=1, config=cfg)
        assert bool(jnp.all(sh["traits"] == sq["traits"]))

        m = SIRModel(SIRConfig(n_agents=400, k=6, subset_size=25))
        st0 = m.init_state(jax.random.key(0))
        sh, _ = run_engine(m, st0, 64, seed=1, config=cfg, engine="sharded")
        sq = run_oracle(m, st0, 64, seed=1, config=cfg)
        assert bool(jnp.all(sh["states"] == sq["states"]))
        assert bool(jnp.all(sh["new_states"] == sq["new_states"]))
        print("OK")
    """)
    assert "OK" in out


def test_sharded_strict_only_guarantee_documented():
    """Under the paper's non-strict record rule the engines may diverge
    from the oracle (missing anti-dependences) — but sharded and
    single-device wavefront must still agree with *each other*: sharding
    is a layout transform of the same wave schedule."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine
        from repro.mabs.voter import VoterModel
        from repro.topology import watts_strogatz

        m = VoterModel(watts_strogatz(128, 4, 0.2, jax.random.key(9)))
        st0 = m.init_state(jax.random.key(4))
        cfg = ProtocolConfig(window=64, strict=False)
        sh, _ = run_engine(m, st0, 150, seed=5, config=cfg, engine="sharded")
        wf, _ = run_engine(m, st0, 150, seed=5, config=cfg,
                           engine="wavefront")
        assert bool(jnp.all(sh["opinions"] == wf["opinions"]))
        print("OK")
    """)
    assert "OK" in out
