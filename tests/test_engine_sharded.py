"""Multi-device sharded-engine tests — run in subprocesses with 8 host
devices (the main test process must keep the default 1-device view).

Acceptance sweep: ShardedEngine bit-exact vs the sequential oracle on
voter and SIS over ring / lattice / Watts-Strogatz topologies, for full
and partial windows, including an agent count the device count does not
divide (exercising the padded shard path).
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, p.stderr[-4000:]
    return p.stdout


@pytest.mark.parametrize("model", ["voter", "sis"])
def test_sharded_bitexact_topology_sweep(model):
    out = run_py(f"""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8, jax.device_count()
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.mabs.sis import SISModel
        from repro.mabs.voter import VoterModel
        from repro.topology import lattice2d, ring, watts_strogatz

        make = {{"voter": VoterModel, "sis": SISModel}}["{model}"]
        cfg = ProtocolConfig(window=64, strict=True)
        topos = {{
            # n=100: 8 does not divide -> padded shard path
            "ring": ring(100, 4),
            "lattice": lattice2d(10, 10, neighborhood="von_neumann"),
            "watts_strogatz": watts_strogatz(128, 4, 0.1, jax.random.key(2)),
        }}
        for name, topo in topos.items():
            m = make(topo)
            st0 = m.init_state(jax.random.key(7))
            # 128 = two full windows; 150 adds a partial tail window
            for total in (128, 150):
                sh, stats = run_engine(m, st0, total, seed=3, config=cfg,
                                       engine="sharded")
                sq = run_oracle(m, st0, total, seed=3, config=cfg)
                leaf = next(iter(st0))
                assert stats["n_devices"] == 8
                assert bool(jnp.all(sh[leaf] == sq[leaf])), (name, total)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_bitexact_axelrod_and_sir():
    """Beyond the acceptance matrix: the ownership contract also covers
    Axelrod (per-feature writes) and SIRS (contiguous block writes over
    two state buffers)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
        from repro.mabs.sir import SIRConfig, SIRModel

        cfg = ProtocolConfig(window=64, strict=True)
        m = AxelrodModel(AxelrodConfig(n_agents=41, n_features=3, q=3))
        st0 = m.init_state(jax.random.key(0))
        sh, _ = run_engine(m, st0, 100, seed=1, config=cfg, engine="sharded")
        sq = run_oracle(m, st0, 100, seed=1, config=cfg)
        assert bool(jnp.all(sh["traits"] == sq["traits"]))

        m = SIRModel(SIRConfig(n_agents=400, k=6, subset_size=25))
        st0 = m.init_state(jax.random.key(0))
        sh, _ = run_engine(m, st0, 64, seed=1, config=cfg, engine="sharded")
        sq = run_oracle(m, st0, 64, seed=1, config=cfg)
        assert bool(jnp.all(sh["states"] == sq["states"]))
        assert bool(jnp.all(sh["new_states"] == sq["new_states"]))
        print("OK")
    """)
    assert "OK" in out


def test_halo_comm_volume_monotone_ladder():
    """The comm ladder is monotone end to end: summed per-wave slab
    bytes (split) <= window-halo bytes <= full-state bytes over the same
    schedule, every rung bit-exact vs the oracle. Also pins the
    monolithic rung's O(max_degree · window) halo width and the
    replicated baseline's full-state accounting."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.mabs.sis import SISModel
        from repro.mabs.voter import VoterModel
        from repro.topology import watts_strogatz

        topo = watts_strogatz(4096, 4, 0.1, jax.random.key(2))
        cfg = ProtocolConfig(window=128, strict=True)
        for make, leaf, n_reads in ((VoterModel, "opinions", 1),
                                    (SISModel, "states",
                                     topo.max_degree + 1)):
            m = make(topo)
            st0 = m.init_state(jax.random.key(7))
            sq = run_oracle(m, st0, 256, seed=3, config=cfg)
            sp, stats = run_engine(m, st0, 256, seed=3, config=cfg,
                                   engine="sharded")
            assert bool(jnp.all(sp[leaf] == sq[leaf]))
            assert stats["halo"] and stats["halo_split"], stats
            # monolithic reference width = W * (reads + writes) rows
            assert stats["window_halo_rows"] == 128 * (n_reads + 1)

            mono, mstats = run_engine(m, st0, 256, seed=3, config=cfg,
                                      engine="sharded_window_halo")
            assert bool(jnp.all(mono[leaf] == sq[leaf]))
            assert mstats["halo"] and not mstats["halo_split"]
            assert mstats["per_wave_gather_rows"] == 128 * (n_reads + 1)
            assert mstats["comm_bytes_total"] == (
                mstats["per_wave_comm_bytes"] * mstats["total_waves"])

            rep, rstats = run_engine(m, st0, 256, seed=3, config=cfg,
                                     engine="sharded_replicated")
            assert bool(jnp.all(rep[leaf] == sq[leaf]))
            assert not rstats["halo"]
            assert rstats["per_wave_comm_bytes"] == rstats["full_state_bytes"]

            # identical schedule across rungs -> comparable totals; the
            # ladder must be monotone per wave and in total
            assert stats["total_waves"] == mstats["total_waves"]
            assert stats["per_wave_comm_bytes"] < mstats["per_wave_comm_bytes"]
            assert mstats["per_wave_comm_bytes"] < rstats["per_wave_comm_bytes"]
            assert stats["comm_bytes_total"] <= mstats["comm_bytes_total"]
            assert mstats["comm_bytes_total"] <= rstats["comm_bytes_total"]
        print("OK")
    """)
    assert "OK" in out


def test_per_wave_comm_regression():
    """CI comm-regression gate (engines-multidevice job): on the voter
    and SIS smoke configs the per-wave split must ship strictly fewer
    bytes per wave than the monolithic window halo, with per-config
    reduction floors just below the measured values (the schedule-time
    specialization is the point of the split; a layout regression shows
    up here before it shows up in BENCH_engine.json)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.mabs.sis import SISModel
        from repro.mabs.voter import VoterModel
        from repro.topology import watts_strogatz

        topo = watts_strogatz(4096, 4, 0.1, jax.random.key(2))
        for make, leaf, window, min_red in (
                (VoterModel, "opinions", 128, 1.7),
                (VoterModel, "opinions", 256, 2.5),
                (SISModel, "states", 128, 2.5),
                (SISModel, "states", 256, 4.0)):
            cfg = ProtocolConfig(window=window, strict=True)
            m = make(topo)
            st0 = m.init_state(jax.random.key(7))
            sp, stats = run_engine(m, st0, 2 * window, seed=3, config=cfg,
                                   engine="sharded")
            sq = run_oracle(m, st0, 2 * window, seed=3, config=cfg)
            assert bool(jnp.all(sp[leaf] == sq[leaf]))
            assert stats["halo_split"], stats
            assert stats["per_wave_comm_bytes"] < stats["window_halo_bytes"]
            red = stats["comm_reduction_vs_window_halo"]
            assert red >= min_red, (make.__name__, window, stats)
        print("OK")
    """)
    assert "OK" in out


def test_halo_fallback_without_row_contracts():
    """A model that declares no task_read_agents must auto-route to the
    replicated layout (and stay exact); halo=True on such a model is a
    loud error rather than silent wrong answers."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.engine import make_engine
        from repro.mabs.voter import VoterModel
        from repro.topology import ring

        class NoContractVoter(VoterModel):
            def task_read_agents(self, recipes):
                return None
            def task_write_agents(self, recipes):
                return None

        m = NoContractVoter(ring(100, 4))
        st0 = m.init_state(jax.random.key(0))
        cfg = ProtocolConfig(window=64, strict=True)
        sh, stats = run_engine(m, st0, 150, seed=1, config=cfg,
                               engine="sharded")
        sq = run_oracle(m, st0, 150, seed=1, config=cfg)
        assert bool(jnp.all(sh["opinions"] == sq["opinions"]))
        assert not stats["halo"]
        try:
            make_engine("sharded", m, window=64, halo=True)
        except ValueError as e:
            assert "task_read_agents" in str(e)
        else:
            raise AssertionError("halo=True should reject contract-less models")
        print("OK")
    """)
    assert "OK" in out


def test_halo_degenerate_width_falls_back_to_replication():
    """The monolithic rung's build-time guard: halo width >= N must drop
    to the replicated layout (shipping the whole halo would cost more
    than the full state) while staying bit-exact — including the overlap
    case, where the *pair* halo (2·W·slots) is the operative width: a
    window size whose single halo still beats N can exceed it once
    doubled. The split rung is exempt from the width guard (it ships
    per-wave slabs, not the whole halo) and must stay engaged — and
    exact — on the same degenerate shapes."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.mabs.voter import VoterModel
        from repro.topology import ring

        # voter: halo slots = 1 read + 1 write = 2 per task
        cfg = ProtocolConfig(window=32, strict=True)

        # W=32 -> halo 64 >= 48 agents: the monolithic rung replicates,
        # but still exact
        m = VoterModel(ring(48, 4))
        st0 = m.init_state(jax.random.key(0))
        sq = run_oracle(m, st0, 70, seed=1, config=cfg)
        sh, stats = run_engine(m, st0, 70, seed=1, config=cfg,
                               engine="sharded_window_halo")
        assert bool(jnp.all(sh["opinions"] == sq["opinions"]))
        assert not stats["halo"], stats
        assert stats["per_wave_gather_rows"] == 48  # padded N, full state
        assert stats["per_wave_comm_bytes"] == stats["full_state_bytes"]
        # ...while the split rung needs no guard: per-wave slabs stay
        # narrow even though the whole halo would not
        sp, sstats = run_engine(m, st0, 70, seed=1, config=cfg,
                                engine="sharded")
        assert bool(jnp.all(sp["opinions"] == sq["opinions"]))
        assert sstats["halo"] and sstats["halo_split"], sstats

        # N=100: single halo 64 < 100 engages, pair halo 128 >= 100 does not
        m = VoterModel(ring(100, 4))
        st0 = m.init_state(jax.random.key(0))
        sh, stats = run_engine(m, st0, 150, seed=1, config=cfg,
                               engine="sharded_window_halo")
        assert stats["halo"] and stats["per_wave_gather_rows"] == 64, stats
        sq = run_oracle(m, st0, 150, seed=1, config=cfg)
        ov, ostats = run_engine(m, st0, 150, seed=1, config=cfg,
                                engine="sharded_window_halo", overlap=True)
        assert bool(jnp.all(ov["opinions"] == sq["opinions"]))
        # pair width tripped the guard: every fused drain replicated —
        # only the partnerless final drain may use the single-window halo
        assert ostats["comm_modes"].get("pair", 0) == 0, ostats
        assert ostats["comm_modes"].get("full", 0) == ostats["n_boundaries"]
        # split rung: fused-wave slabs beat both the pair halo and the
        # full state on the same run
        ov, ostats = run_engine(m, st0, 150, seed=1, config=cfg,
                                engine="sharded_overlap")
        assert bool(jnp.all(ov["opinions"] == sq["opinions"]))
        assert ostats["halo"] and ostats["halo_split"], ostats

        # and a size where even the pair halo wins: N=4096
        from repro.topology import watts_strogatz
        topo = watts_strogatz(4096, 4, 0.1, jax.random.key(2))
        m = VoterModel(topo)
        st0 = m.init_state(jax.random.key(7))
        sq = run_oracle(m, st0, 128, seed=3, config=cfg)
        ov, ostats = run_engine(m, st0, 128, seed=3, config=cfg,
                                engine="sharded_window_halo", overlap=True)
        assert bool(jnp.all(ov["opinions"] == sq["opinions"]))
        assert ostats["halo"] and ostats["per_wave_gather_rows"] == 128
        print("OK")
    """)
    assert "OK" in out


def test_halo_probe_mixed_contracts():
    """The construction-time probe must treat a model with only *one* of
    the two row contracts as contract-less: auto-route to replication,
    and reject halo=True loudly."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.engine import make_engine
        from repro.mabs.voter import VoterModel
        from repro.topology import ring

        class WriteOnlyVoter(VoterModel):
            def task_read_agents(self, recipes):
                return None   # writes declared, reads not

        m = WriteOnlyVoter(ring(100, 4))
        st0 = m.init_state(jax.random.key(0))
        cfg = ProtocolConfig(window=32, strict=True)
        sh, stats = run_engine(m, st0, 100, seed=1, config=cfg,
                               engine="sharded")
        sq = run_oracle(m, st0, 100, seed=1, config=cfg)
        assert bool(jnp.all(sh["opinions"] == sq["opinions"]))
        assert not stats["halo"]
        try:
            make_engine("sharded", m, window=32, halo=True)
        except ValueError as e:
            assert "task_read_agents" in str(e)
        else:
            raise AssertionError("halo=True must reject mixed contracts")
        print("OK")
    """)
    assert "OK" in out


def test_single_device_mesh_degenerates_to_no_comm():
    """A sharded engine built on a single-device mesh must degenerate to
    the single-device semantics: one shard owns everything, every task
    is owned, the halo gather is a self-psum — bit-exact, n_devices=1,
    and the same totals as the wavefront engine."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine, run_oracle
        from repro.engine import make_engine
        from repro.mabs.sis import SISModel
        from repro.topology import watts_strogatz

        topo = watts_strogatz(512, 4, 0.1, jax.random.key(2))
        m = SISModel(topo)
        st0 = m.init_state(jax.random.key(7))
        cfg = ProtocolConfig(window=64, strict=True)
        sq = run_oracle(m, st0, 150, seed=3, config=cfg)
        for ename in ("sharded", "sharded_overlap", "sharded_window_halo",
                      "sharded_replicated"):
            eng = make_engine(ename, m, window=64,
                              devices=jax.devices()[:1])
            sh, stats = eng.run(st0, 150, seed=3)
            assert stats["n_devices"] == 1, (ename, stats)
            assert bool(jnp.all(sh["states"] == sq["states"])), ename
        wf, wstats = run_engine(m, st0, 150, seed=3, config=cfg,
                                engine="wavefront")
        sh, sstats = make_engine("sharded", m, window=64,
                                 devices=jax.devices()[:1]).run(
                                     st0, 150, seed=3)
        assert sstats["total_waves"] == wstats["total_waves"]
        print("OK")
    """)
    assert "OK" in out


def test_sharded_strict_only_guarantee_documented():
    """Under the paper's non-strict record rule the engines may diverge
    from the oracle (missing anti-dependences) — but sharded and
    single-device wavefront must still agree with *each other*: sharding
    is a layout transform of the same wave schedule."""
    out = run_py("""
        import jax, jax.numpy as jnp
        assert jax.device_count() == 8
        from repro.core import ProtocolConfig, run_engine
        from repro.mabs.voter import VoterModel
        from repro.topology import watts_strogatz

        m = VoterModel(watts_strogatz(128, 4, 0.2, jax.random.key(9)))
        st0 = m.init_state(jax.random.key(4))
        cfg = ProtocolConfig(window=64, strict=False)
        sh, _ = run_engine(m, st0, 150, seed=5, config=cfg, engine="sharded")
        wf, _ = run_engine(m, st0, 150, seed=5, config=cfg,
                           engine="wavefront")
        assert bool(jnp.all(sh["opinions"] == wf["opinions"]))
        print("OK")
    """)
    assert "OK" in out
