"""Compiled-cost telemetry and benchmark-sentinel tests.

Pins the contracts docs/observability.md promises for the cost layer:

  * the HLO collective walker classifies ops by dynamic while depth,
    folds static trip counts in, skips async ``-done`` halves, and
    parses both replica-group print forms;
  * ``compiled_costs`` × ``comm_iteration_counts`` reproduces the
    runtime comm ledger's byte total **exactly** on the sharded rungs
    (the 8-virtual-device subprocess lane, same pattern as
    test_engine_differential.py);
  * ``report.py compare`` verdicts are golden — regressed / improved /
    neutral with dispersion-widened thresholds, backend mismatch is
    warn-only, and ``--gate`` exits nonzero only on a real regression;
  * ``fit_tn_cost_model`` recovers planted T(W, n) coefficients from
    synthetic sweep rows;
  * ``finalize_stats`` rejects non-finite values; ``median_time``
    carries its full sample list.
"""
import json
import math
import os
import subprocess
import sys
import textwrap

import pytest

from conftest import BASE_SEED

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# --------------------------------------------------------------------------
# HLO collective parsing on synthetic modules

# one dynamic wave loop (no constant trip in its condition) holding an
# async all-gather pair and, nested inside, a second dynamic chunk loop
# with a collective-permute; plus a statically-counted loop (trips=5)
# with an all-reduce at top level
SYNTH_HLO = textwrap.dedent("""\
    %chunk_cond (p.0: (s32[], f32[8])) -> pred[] {
      %lt.0 = pred[] compare(s32[] %a, s32[] %b), direction=LT
    }

    %chunk_body (p.1: (s32[], f32[8])) -> (s32[], f32[8]) {
      %cp = f32[8]{0} collective-permute(f32[8] %x), channel_id=3, source_target_pairs={{0,1},{1,2}}
    }

    %wave_cond (p.2: (s32[], f32[16])) -> pred[] {
      %lt.1 = pred[] compare(s32[] %c, s32[] %d), direction=LT
    }

    %wave_body (p.3: (s32[], f32[16])) -> (s32[], f32[16]) {
      %ags = f32[16]{0} all-gather-start(f32[2] %y), channel_id=1, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}
      %agd = f32[16]{0} all-gather-done(f32[16]{0} %ags)
      %w.1 = (s32[], f32[8]) while((s32[], f32[8]) %t0), condition=%chunk_cond, body=%chunk_body
    }

    %scan_cond (p.4: (s32[], f32[4])) -> pred[] {
      %c.5 = s32[] constant(5)
      %lt.2 = pred[] compare(s32[] %e, s32[] %c.5), direction=LT
    }

    %scan_body (p.5: (s32[], f32[4])) -> (s32[], f32[4]) {
      %ar = f32[4]{0} all-reduce(f32[4] %z), channel_id=2, replica_groups=[2,4], to_apply=%add
    }

    ENTRY %main (arg0: f32[16]) -> f32[16] {
      %w.2 = (s32[], f32[16]) while((s32[], f32[16]) %t1), condition=%wave_cond, body=%wave_body
      %w.3 = (s32[], f32[4]) while((s32[], f32[4]) %t2), condition=%scan_cond, body=%scan_body
    }
    """)


def test_parse_depth_classification_and_static_trips():
    from repro.obs.costs import parse_collectives

    coll = parse_collectives(SYNTH_HLO)
    by_op = {o.op: o for o in coll.ops}
    assert set(by_op) == {"all-gather", "collective-permute", "all-reduce"}
    # dynamic wave loop → depth 1; nested dynamic chunk loop → depth 2
    assert by_op["all-gather"].depth == 1
    assert by_op["collective-permute"].depth == 2
    # statically-counted loop stays depth 0 with the trip multiplier
    ar = by_op["all-reduce"]
    assert ar.depth == 0 and ar.static_mult == 5
    # per-depth per-call bytes: f32[16]=64, f32[8]=32, f32[4]*5=80
    assert coll.bytes_by_depth() == {1: 64, 2: 32, 0: 80}


def test_parse_skips_async_done_half():
    from repro.obs.costs import parse_collectives

    coll = parse_collectives(SYNTH_HLO)
    # the -done completion must not double-count the all-gather
    assert sum(1 for o in coll.ops if o.op == "all-gather") == 1


def test_parse_replica_group_forms():
    from repro.obs.costs import _group_size

    assert _group_size(
        "x, replica_groups={{0,1,2,3,4,5,6,7}}, dims") == 8
    assert _group_size("x, replica_groups={{0,1},{2,3}}, dims") == 2
    assert _group_size("x, replica_groups=[2,4], more") == 4
    assert _group_size("no groups here") is None


def test_total_and_wire_bytes_accounting():
    from repro.obs.costs import parse_collectives

    coll = parse_collectives(SYNTH_HLO)
    # executed counts: 7 waves, 3 chunk trips; depth-0 runs once per call
    iters = {0: 1, 1: 7, 2: 3}
    assert coll.total_bytes(iters) == 64 * 7 + 32 * 3 + 80
    # wire model applies per-op ring factors on the same accounting
    assert coll.wire_bytes(iters) > 0


def test_executor_cost_on_jitted_fn():
    import jax
    import jax.numpy as jnp

    from repro.obs.costs import executor_cost

    @jax.jit
    def f(x):
        return (x * 2.0 + 1.0).sum()

    cost = executor_cost(f, jnp.ones((64,), jnp.float32), name="toy")
    assert cost.name == "toy"
    assert cost.flops > 0
    assert cost.bytes_accessed >= 64 * 4
    assert cost.peak_bytes >= cost.output_bytes
    assert not cost.collectives.ops
    row = cost.as_row({1: 3})
    json.dumps(row)  # must be JSON-safe
    assert row["collective_bytes"] == 0


def test_ledger_cross_check_exact_and_mismatch():
    from repro.obs.costs import (CollectiveOp, ExecutorCost,
                                 HloCollectives, ledger_cross_check)

    coll = HloCollectives(ops=[CollectiveOp(
        op="all-gather", type_str="f32[16]", bytes_per_call=64,
        static_mult=1, depth=1, group_size=8)])
    cost = ExecutorCost(name="x", flops=0, bytes_accessed=0,
                        argument_bytes=0, output_bytes=0, temp_bytes=0,
                        collectives=coll)
    chk = ledger_cross_check({"x": cost}, {1: 7}, 64 * 7)
    assert chk.ok and chk.ratio == 1.0 and chk.parsed_bytes == 448
    chk = ledger_cross_check([cost], {1: 7}, 64 * 7 + 1)
    assert not chk.ok


# --------------------------------------------------------------------------
# compare verdicts (golden)


def _row(tps, seconds=1.0, samples=None, **over):
    r = {"kind": "engine", "model": "voter", "engine": "wavefront",
         "topology": "ws", "window": 64, "n_devices": 1, "n_agents": 512,
         "tasks_per_s": tps, "seconds": seconds}
    if samples is not None:
        r["seconds_samples"] = list(samples)
    r.update(over)
    return r


def _payload(rows, backend="cpu"):
    return {"meta": {"provenance": {"backend": backend}}, "rows": rows}


def test_compare_golden_verdicts():
    sys.path.insert(0, REPO)
    from benchmarks.report import compare_benches

    old = _payload([_row(100.0),
                    _row(100.0, engine="sharded"),
                    _row(100.0, engine="sharded_replicated")])
    new = _payload([_row(50.0),                               # 0.5x
                    _row(200.0, engine="sharded"),            # 2.0x
                    _row(105.0, engine="sharded_replicated"),  # within t
                    _row(99.0, engine="brand_new")])
    cmp = compare_benches(old, new, threshold=0.15)
    verdicts = {r["key"][2]: r["verdict"] for r in cmp["rows"]}
    assert verdicts == {"wavefront": "regressed", "sharded": "improved",
                        "sharded_replicated": "neutral",
                        "brand_new": "new"}
    assert not cmp["warn_only"]
    assert cmp["unmatched_old"] == 0
    assert len(cmp["regressed"]) == 1


def test_compare_dispersion_widens_threshold():
    from benchmarks.report import compare_benches

    # a 0.75x move regresses a quiet row, but a row whose repeats spread
    # (max-min)/median = 0.2 gets an effective threshold of 0.4 and the
    # same move is neutral
    old_q = _payload([_row(100.0)])
    new_q = _payload([_row(75.0)])
    assert compare_benches(old_q, new_q, 0.15)["rows"][0]["verdict"] \
        == "regressed"
    noisy = [0.9, 1.0, 1.1]
    old_n = _payload([_row(100.0, samples=noisy)])
    new_n = _payload([_row(75.0, samples=noisy)])
    row = compare_benches(old_n, new_n, 0.15)["rows"][0]
    assert row["verdict"] == "neutral"
    assert row["threshold"] == pytest.approx(0.4)


def test_compare_backend_mismatch_warn_only():
    from benchmarks.report import compare_benches

    old = _payload([_row(100.0)], backend="tpu")
    new = _payload([_row(10.0)], backend="cpu")
    cmp = compare_benches(old, new, 0.15)
    assert cmp["warn_only"]
    # verdicts still render — the gate just never fails on them
    assert cmp["rows"][0]["verdict"] == "regressed"


def test_compare_incomparable_and_unmatched():
    from benchmarks.report import compare_benches

    old = _payload([_row(100.0), _row(100.0, engine="gone")])
    new = _payload([_row(None)])
    cmp = compare_benches(old, new, 0.15)
    assert cmp["rows"][0]["verdict"] == "incomparable"
    assert cmp["unmatched_old"] == 1


# --------------------------------------------------------------------------
# the gate, end to end (subprocess — real exit codes)


def _run_compare(old_path, new_path, *extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.report", "compare",
         str(old_path), str(new_path), "--gate", *extra],
        capture_output=True, text=True, timeout=120, env=env, cwd=REPO)


def test_gate_passes_on_committed_artifact():
    bench = os.path.join(REPO, "BENCH_engine.json")
    p = _run_compare(bench, bench)
    assert p.returncode == 0, p.stdout + p.stderr
    assert "GATE: PASS" in p.stdout


def test_gate_fails_on_injected_regression(tmp_path):
    bench = os.path.join(REPO, "BENCH_engine.json")
    with open(bench) as f:
        payload = json.load(f)
    n_injected = 0
    for r in payload["rows"]:
        if r.get("tasks_per_s"):
            r["tasks_per_s"] *= 0.1
            n_injected += 1
    assert n_injected, "committed BENCH must carry tasks_per_s rows"
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text(json.dumps(payload))
    p = _run_compare(bench, bad)
    assert p.returncode == 1, p.stdout + p.stderr
    assert "GATE: FAIL" in p.stdout
    # same injected regression under a backend mismatch → warn-only, passes
    payload["meta"].setdefault("provenance", {})["backend"] = "tpu"
    bad.write_text(json.dumps(payload))
    p = _run_compare(bench, bad)
    assert p.returncode == 0, p.stdout + p.stderr


# --------------------------------------------------------------------------
# T(W, n) cost-model fit recovers planted coefficients


def test_fit_tn_cost_model_recovery():
    from benchmarks.roofline import TN_FEATURES, fit_tn_cost_model

    planted = {"c_sched[s/W^2]": 1e-7, "c_wave[s/wave]": 2e-3,
               "c_agent[s/(wave*n)]": 1e-6, "c0[s]": 0.05}
    assert set(planted) == set(TN_FEATURES)
    rows = []
    for fam_i, fam in enumerate(("ws", "ba", "grid2d", "er", "complete")):
        for w in (8, 32, 128):
            for n in (64, 256, 1024):
                total = 4 * n
                waves = total // max(1, w // 8) + fam_i  # vary per family
                n_windows = max(total // w, 1)
                sec = (planted["c_sched[s/W^2]"] * n_windows * w ** 2
                       + planted["c_wave[s/wave]"] * waves
                       + planted["c_agent[s/(wave*n)]"] * waves * n
                       + planted["c0[s]"])
                rows.append({"model": "voter", "topology": fam,
                             "window": w, "n_agents": n,
                             "total_tasks": total, "total_waves": waves,
                             "seconds": sec})
    (fit,) = fit_tn_cost_model(rows)
    assert fit["model"] == "voter" and fit["n_rows"] == len(rows)
    assert fit["r2"] > 0.9999
    assert fit["rms_rel"] < 1e-6
    for name, want in planted.items():
        assert fit["coef"][name] == pytest.approx(want, rel=1e-4)
    assert set(fit["residuals_by_family"]) \
        == {"ws", "ba", "grid2d", "er", "complete"}


# --------------------------------------------------------------------------
# satellite contracts: non-finite stats rejected, timing carries samples


def test_finalize_stats_rejects_nonfinite():
    from repro.obs import finalize_stats

    base = {"total_tasks": 40, "n_windows": 2, "total_waves": 10,
            "mean_parallelism": 4.0}
    assert finalize_stats(dict(base))["mean_parallelism"] == 4.0
    for bad in (float("nan"), float("inf"), -float("inf")):
        with pytest.raises(ValueError, match="non-finite"):
            finalize_stats({**base, "mean_parallelism": bad})
    with pytest.raises(ValueError, match="non-finite"):
        finalize_stats({**base, "total_waves": float("nan")})


def test_median_time_returns_samples():
    from repro.utils.timing import TimingResult, median_time

    t = median_time(lambda: math.sqrt(2.0), repeats=5)
    assert isinstance(t, TimingResult) and isinstance(t, float)
    assert len(t.samples) == 5
    assert list(t.samples) == sorted(t.samples)
    assert t.min_s == t.samples[0]
    assert float(t) == t.samples[2]  # median of 5 sorted repeats
    assert t.rel_spread >= 0.0
    # degenerate single repeat: defined, no dispersion
    t1 = median_time(lambda: None, repeats=1)
    assert t1.rel_spread == 0.0


# --------------------------------------------------------------------------
# the tentpole identity under 8 virtual devices: HLO-parsed collective
# bytes × executed iterations == runtime comm ledger, exactly

XCHECK_SCRIPT = textwrap.dedent("""\
    import jax

    from repro.engine import make_engine
    from repro.mabs.voter import VoterModel
    from repro.obs.costs import ledger_cross_check
    from repro.topology import watts_strogatz

    topo = watts_strogatz(256, 4, 0.1, jax.random.key({seed}))
    model = VoterModel(topo)
    for name in ("sharded", "sharded_window_halo"):
        eng = make_engine(name, model, window=16)
        state = model.init_state(jax.random.key({seed} + 1))
        state, stats = eng.run(state, 80, seed={seed} + 2)
        # read the executed iteration counts BEFORE compiled_costs: the
        # AOT path re-prepares state, which resets the comm ledger
        iters = eng.comm_iteration_counts(stats)
        costs = eng.compiled_costs(state, seed={seed} + 2)
        assert costs, name
        chk = ledger_cross_check(costs, iters,
                                 stats["comm_bytes_total"])
        print(name, chk.parsed_bytes, chk.ledger_bytes, chk.ratio)
        assert chk.ok, (name, chk)
    print("XCHECK-OK")
    """)


def test_cost_ledger_cross_check_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run(
        [sys.executable, "-c", XCHECK_SCRIPT.format(seed=BASE_SEED)],
        capture_output=True, text=True, timeout=560, env=env, cwd=REPO)
    assert p.returncode == 0, p.stderr[-4000:]
    assert "XCHECK-OK" in p.stdout
