"""Wave-level kernel sweeps (Pallas interpret vs the scan reference) and
the finite-worker list-scheduling invariants of ``wave_levels_capped``."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.records import wave_levels, wave_levels_capped
from repro.kernels.levels.levels import wave_levels_pallas
from repro.kernels.levels.ops import wave_levels as wave_levels_op
from repro.kernels.levels.ref import wave_levels_ref


def _random_window(seed, *, lower=True):
    rng = np.random.RandomState(seed)
    w = rng.randint(3, 300)
    density = rng.rand() * 0.6
    conf = rng.rand(w, w) < density
    if lower:
        conf = np.tril(conf, k=-1)
    valid = rng.rand(w) < (1.0 if seed % 3 else 0.8)
    return conf, valid


# ------------------------------------------------------------ pallas kernel
@pytest.mark.parametrize("seed", range(25))
def test_levels_pallas_matches_scan(seed):
    """Blocked kernel == scan reference on random (padded, partly invalid)
    windows, across block boundaries (w up to 300 with 128-blocks)."""
    conf, valid = _random_window(seed)
    ref = wave_levels_ref(jnp.asarray(conf), jnp.asarray(valid))
    out = wave_levels_pallas(jnp.asarray(conf), jnp.asarray(valid),
                             interpret=True)
    assert bool(jnp.all(out == ref))


@pytest.mark.parametrize("seed", range(8))
def test_levels_pallas_arbitrary_matrices(seed):
    """Same convention as the scan for non-lower-triangular inputs:
    at/above-diagonal entries and invalid targets contribute nothing."""
    conf, valid = _random_window(seed, lower=False)
    ref = wave_levels_ref(jnp.asarray(conf), jnp.asarray(valid))
    out = wave_levels_pallas(jnp.asarray(conf), jnp.asarray(valid),
                             interpret=True)
    assert bool(jnp.all(out == ref))


@pytest.mark.parametrize("w", [1, 2, 128, 129, 256])
def test_levels_pallas_shapes(w):
    rng = np.random.RandomState(w)
    conf = np.tril(rng.rand(w, w) < 0.3, k=-1)
    valid = np.ones(w, bool)
    ref = wave_levels_ref(jnp.asarray(conf), jnp.asarray(valid))
    out = wave_levels_pallas(jnp.asarray(conf), jnp.asarray(valid),
                             interpret=True)
    assert bool(jnp.all(out == ref))


# ------------------------------------------------- carry-over base floor
def _brute_levels_with_base(conf, valid, base):
    """O(W²) host-side oracle for the floored recurrence."""
    w = conf.shape[0]
    lv = np.full(w, -1, np.int64)
    for i in range(w):
        if not valid[i]:
            continue
        deps = [lv[j] for j in range(i) if conf[i, j]]
        lv[i] = max(int(base[i]), (max(deps) + 1) if deps else 0)
    return lv


@pytest.mark.parametrize("seed", range(12))
def test_levels_base_floor_matches_brute_force(seed):
    """The overlapped engines' carry frontier enters as a per-task level
    floor; scan ref and blocked Pallas kernel must both honor it."""
    conf, valid = _random_window(seed)
    rng = np.random.RandomState(seed + 1000)
    base = rng.randint(0, 9, size=conf.shape[0])
    brute = _brute_levels_with_base(conf, valid, base)
    ref = wave_levels_ref(jnp.asarray(conf), jnp.asarray(valid),
                          jnp.asarray(base, jnp.int32))
    out = wave_levels_pallas(jnp.asarray(conf), jnp.asarray(valid),
                             jnp.asarray(base, jnp.int32), interpret=True)
    assert (np.asarray(ref) == brute).all()
    assert bool(jnp.all(out == ref))


def test_levels_base_zero_is_classic_recurrence():
    conf, valid = _random_window(5)
    zero = jnp.zeros((conf.shape[0],), jnp.int32)
    assert bool(jnp.all(
        wave_levels_ref(jnp.asarray(conf), jnp.asarray(valid))
        == wave_levels_ref(jnp.asarray(conf), jnp.asarray(valid), zero)))
    assert bool(jnp.all(
        wave_levels(jnp.asarray(conf), jnp.asarray(valid))
        == wave_levels(jnp.asarray(conf), jnp.asarray(valid), base=zero)))


def test_levels_op_backends_and_default():
    conf, valid = _random_window(11)
    ref = wave_levels_ref(jnp.asarray(conf), jnp.asarray(valid))
    for backend in ("jnp", "pallas"):
        out = wave_levels_op(conf, valid, backend=backend,
                             interpret=True)
        assert bool(jnp.all(out == ref))
    # core.records.wave_levels is the auto-detect route execute_window uses
    assert bool(jnp.all(wave_levels(jnp.asarray(conf),
                                    jnp.asarray(valid)) == ref))
    with pytest.raises(ValueError):
        wave_levels_op(conf, valid, backend="cuda")


# ------------------------------------------------------ wave_levels_capped
@pytest.mark.parametrize("seed", range(20))
def test_capped_matches_uncapped_at_infinite_workers(seed):
    """n_workers >= W removes every capacity constraint: the capped
    schedule degenerates to the pure dependence levels."""
    conf, valid = _random_window(seed)
    w = conf.shape[0]
    lv = np.asarray(wave_levels(jnp.asarray(conf), jnp.asarray(valid)))
    capped = wave_levels_capped(conf, valid, n_workers=w)
    assert (capped == lv).all()


@pytest.mark.parametrize("seed", range(20))
@pytest.mark.parametrize("n_workers", [1, 2, 5])
def test_capped_capacity_invariant(seed, n_workers):
    """No wave may hold more than n_workers tasks."""
    conf, valid = _random_window(seed)
    capped = wave_levels_capped(conf, valid, n_workers=n_workers)
    lv = capped[capped >= 0]
    if lv.size:
        assert np.bincount(lv).max() <= n_workers
    assert (capped[~np.asarray(valid)] == -1).all()


@pytest.mark.parametrize("seed", range(20))
@pytest.mark.parametrize("n_workers", [1, 3])
def test_capped_lower_bounded_by_dependence_levels(seed, n_workers):
    """Capacity can only push tasks later: capped >= uncapped level, and
    dependencies still strictly order the waves."""
    conf, valid = _random_window(seed)
    lv = np.asarray(wave_levels(jnp.asarray(conf), jnp.asarray(valid)))
    capped = wave_levels_capped(conf, valid, n_workers=n_workers)
    v = np.asarray(valid)
    assert (capped[v] >= lv[v]).all()
    ii, jj = np.nonzero(np.asarray(conf) & v[:, None] & v[None, :]
                        & np.tril(np.ones_like(conf, dtype=bool), k=-1))
    assert (capped[ii] > capped[jj]).all()
