"""Optimizer / schedule / data / checkpoint / loop / compression tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train.checkpoint import CheckpointManager
from repro.train.data import DataConfig, Prefetcher, SyntheticLMStream
from repro.train.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.schedule import cosine_schedule


# ----------------------------------------------------------------- adamw
def test_adamw_matches_reference_numpy():
    cfg = AdamWConfig(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                      grad_clip=1e9)
    p = {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32)}
    st = adamw_init(p)
    lr = 0.1
    m = np.zeros((2, 2)); v = np.zeros((2, 2))
    pw = np.asarray(p["w"]).copy()
    for t in range(1, 6):
        g = {"w": jnp.asarray(pw * 0.3 + 0.1, jnp.float32)}
        p, st, _ = adamw_update(cfg, p, g, st, lr)
        gn = pw * 0.3 + 0.1
        m = 0.9 * m + 0.1 * gn
        v = 0.99 * v + 0.01 * gn * gn
        mh = m / (1 - 0.9 ** t)
        vh = v / (1 - 0.99 ** t)
        pw = pw - lr * mh / (np.sqrt(vh) + 1e-8)
        np.testing.assert_allclose(np.asarray(p["w"]), pw, rtol=1e-4,
                                   atol=1e-5)


def test_adamw_grad_clip():
    cfg = AdamWConfig(grad_clip=1.0, weight_decay=0.0)
    p = {"w": jnp.zeros((4,), jnp.float32)}
    st = adamw_init(p)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = adamw_update(cfg, p, g, st, 0.1)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(0, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    lrw = float(cosine_schedule(10, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    lre = float(cosine_schedule(100, peak_lr=1.0, warmup_steps=10,
                                total_steps=100))
    assert lr0 == 0.0 and lrw == pytest.approx(1.0)
    assert lre == pytest.approx(0.1, abs=1e-6)


# ------------------------------------------------------------------ data
def test_data_deterministic_and_sharded():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8)
    s0 = SyntheticLMStream(cfg, host_id=0, n_hosts=2)
    s1 = SyntheticLMStream(cfg, host_id=1, n_hosts=2)
    a = s0.batch_at(3)
    b = s0.batch_at(3)
    c = s1.batch_at(3)
    assert np.array_equal(a["tokens"], b["tokens"])         # deterministic
    assert not np.array_equal(a["tokens"], c["tokens"])     # host-sharded
    assert a["tokens"].shape == (4, 64)
    # labels are next-token shifted with masked tail
    assert np.array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])
    assert (a["labels"][:, -1] == -100).all()


def test_prefetcher_orders_batches():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    stream = SyntheticLMStream(cfg)
    pf = Prefetcher(stream, start_step=5)
    steps = [pf.next()[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
             "b": {"c": jnp.asarray([1, 2], jnp.int32)}}
    mgr.save(10, state, blocking=True)
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored, step = mgr.restore(like)
    assert step == 10
    assert bool(jnp.all(restored["a"] == state["a"]))
    assert bool(jnp.all(restored["b"]["c"] == state["b"]["c"]))


def test_checkpoint_retention_and_commit(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3):
        mgr.save(s, state, blocking=True)
    assert mgr.committed_steps() == [2, 3]
    # an uncommitted (crashed) dir is ignored
    os.makedirs(tmp_path / "step_00000099")
    assert mgr.latest_step() == 3


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"x": jnp.ones((128, 128))}
    mgr.save(7, state)          # async
    mgr.wait()
    assert mgr.latest_step() == 7


# --------------------------------------------------------------- end2end
def test_training_reduces_loss_and_resumes(tmp_path):
    """Deliverable (b) in miniature: loss must decrease, and a second loop
    must resume from the checkpoint rather than restart."""
    from repro.configs import ARCHS
    from repro.models.api import build_model
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.step import (TrainHParams, init_train_state,
                                  make_train_step)

    cfg = ARCHS["smollm-360m"].reduced()
    model = build_model(cfg)
    hp = TrainHParams(peak_lr=3e-3, warmup_steps=3, total_steps=40)
    step_fn = jax.jit(make_train_step(model, hp))
    state = init_train_state(model, jax.random.key(0))
    stream = SyntheticLMStream(DataConfig(vocab=cfg.vocab, seq_len=32,
                                          global_batch=4))
    loop_cfg = LoopConfig(total_steps=25, ckpt_every=10,
                          ckpt_dir=str(tmp_path))
    state, rep = train_loop(step_fn, state, stream, loop_cfg)
    assert rep.steps_run == 25
    first_loss = rep.final_metrics["loss"]

    # resume: continue to 40
    state2 = init_train_state(model, jax.random.key(0))
    loop_cfg2 = LoopConfig(total_steps=40, ckpt_every=10,
                           ckpt_dir=str(tmp_path))
    state2, rep2 = train_loop(step_fn, state2, stream, loop_cfg2)
    # first loop checkpoints at 10, 20 and at its final step 25
    assert rep2.resumed_from == 25
    assert rep2.steps_run == 15          # 25 -> 40, not from scratch
    assert rep2.final_metrics["loss"] < 7.0
    assert int(np.asarray(state2.step)) == 40


def test_loss_decreases_on_learnable_stream():
    from repro.configs import ARCHS
    from repro.models.api import build_model
    from repro.train.step import (TrainHParams, init_train_state,
                                  make_train_step)

    cfg = ARCHS["smollm-360m"].reduced()
    model = build_model(cfg)
    hp = TrainHParams(peak_lr=3e-3, warmup_steps=5, total_steps=60)
    step_fn = jax.jit(make_train_step(model, hp))
    state = init_train_state(model, jax.random.key(0))
    stream = SyntheticLMStream(DataConfig(vocab=cfg.vocab, seq_len=64,
                                          global_batch=4))
    losses = []
    for s in range(50):
        state, metrics = step_fn(state, stream.batch_at(s))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.5


# ------------------------------------------------------------- compress
def test_error_feedback_compression():
    from repro.distributed.compress import compress_grads, ef_init

    rng = np.random.RandomState(0)
    g = {"w": jnp.asarray(rng.randn(64, 64).astype(np.float32))}
    ef = ef_init(g)
    # single-shot quantization error is bounded by scale/2
    cg, ef2 = compress_grads(g, ef)
    err = np.abs(np.asarray(cg["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127
    assert err.max() <= scale * 0.51 + 1e-6
    # error feedback: accumulated compressed sum converges to true sum
    ef = ef_init(g)
    tot_c = np.zeros((64, 64), np.float32)
    for _ in range(30):
        cg, ef = compress_grads(g, ef)
        tot_c += np.asarray(cg["w"])
    tot_t = np.asarray(g["w"]) * 30
    rel = np.abs(tot_c - tot_t).max() / np.abs(tot_t).max()
    assert rel < 0.02


def test_microbatched_step_matches_single():
    from repro.configs import ARCHS
    from repro.models.api import build_model
    from repro.train.step import (TrainHParams, init_train_state,
                                  make_train_step)

    cfg = ARCHS["smollm-360m"].reduced().replace(param_dtype="float32")
    model = build_model(cfg)
    state = init_train_state(model, jax.random.key(0))
    stream = SyntheticLMStream(DataConfig(vocab=cfg.vocab, seq_len=32,
                                          global_batch=4))
    batch = stream.batch_at(0)
    s1, m1 = jax.jit(make_train_step(
        model, TrainHParams(microbatches=1)))(state, batch)
    s2, m2 = jax.jit(make_train_step(
        model, TrainHParams(microbatches=2)))(state, batch)
    # losses equal (mean over microbatches == full-batch mean here since
    # all sequences have identical token counts)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        s1.params, s2.params)
    assert max(jax.tree_util.tree_leaves(d)) < 5e-5


def test_straggler_watchdog_flags_slow_steps(tmp_path):
    """The loop's step-time EWMA must flag steps slower than
    straggler_factor x the running mean (the host-exclusion signal on a
    real pod)."""
    import time

    from repro.configs import ARCHS
    from repro.models.api import build_model
    from repro.train.loop import LoopConfig, train_loop
    from repro.train.step import (TrainHParams, init_train_state,
                                  make_train_step)

    cfg = ARCHS["smollm-360m"].reduced()
    model = build_model(cfg)
    inner = jax.jit(make_train_step(model, TrainHParams(total_steps=30)))
    state = init_train_state(model, jax.random.key(0))
    stream = SyntheticLMStream(DataConfig(vocab=cfg.vocab, seq_len=16,
                                          global_batch=2))
    calls = {"n": 0}

    def step_fn(st, batch):  # inject an artificial straggler at step 12
        calls["n"] += 1
        if calls["n"] == 12:
            time.sleep(1.0)
        return inner(st, batch)

    loop_cfg = LoopConfig(total_steps=20, ckpt_every=100,
                          ckpt_dir=str(tmp_path), straggler_factor=3.0)
    _, rep = train_loop(step_fn, state, stream, loop_cfg)
    assert 11 in rep.straggler_steps, rep.straggler_steps
