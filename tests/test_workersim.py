"""Protocol-faithful discrete-event simulator invariants."""
import numpy as np
import pytest

from repro.core import DESCosts, ProtocolConfig, simulate_protocol
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
from repro.mabs.sir import SIRConfig, SIRModel


def _axelrod_des(**kw):
    return AxelrodModel(AxelrodConfig(n_agents=200, n_features=20)
                        ).des_model(**kw)


def test_all_tasks_execute():
    r = simulate_protocol(_axelrod_des(), 500,
                          config=ProtocolConfig(n_workers=3))
    assert r.n_tasks == 500
    assert sum(r.executed_per_worker) == 500


def test_single_worker_is_sequential():
    """n=1: exactly one task in flight, chain length stays at C-bound."""
    r = simulate_protocol(_axelrod_des(), 300,
                          config=ProtocolConfig(n_workers=1,
                                                tasks_per_cycle=6))
    assert r.executed_per_worker == [300]
    assert r.max_chain_len <= 6 + 1


def test_more_workers_not_slower_at_large_tasks():
    """Paper Fig. 2 claim (i): T decreases with n when tasks are large."""
    des = AxelrodModel(AxelrodConfig(n_agents=500, n_features=300)
                       ).des_model()
    t1 = simulate_protocol(des, 400, config=ProtocolConfig(n_workers=1)
                           ).makespan
    des = AxelrodModel(AxelrodConfig(n_agents=500, n_features=300)
                       ).des_model()
    t4 = simulate_protocol(des, 400, config=ProtocolConfig(n_workers=4)
                           ).makespan
    assert t4 < t1
    assert t4 > t1 / 4.5  # no super-linear nonsense


def test_makespan_bounded_below_by_work():
    """makespan >= total model work / n (work conservation)."""
    cfg = AxelrodConfig(n_agents=500, n_features=100)
    m = AxelrodModel(cfg)
    des = m.des_model()
    n = 3
    r = simulate_protocol(des, 300, config=ProtocolConfig(n_workers=n))
    per_task = 1e-7 * cfg.n_features + 5e-7
    assert r.makespan >= 300 * per_task / n


def test_sir_des_runs_and_balances():
    m = SIRModel(SIRConfig(n_agents=400, k=6, subset_size=20))
    r = simulate_protocol(m.des_model(), 400,
                          config=ProtocolConfig(n_workers=4))
    assert r.n_tasks == 400
    # all workers participate for a conflict-sparse chain
    assert min(r.executed_per_worker) > 0


def test_protocol_overhead_dominates_small_tasks():
    """Paper Fig. 3 claim: speedup from extra workers degrades as task size
    shrinks (protocol overhead per task is constant). Measured trend on
    this DES: t5/t1 = 0.51 (s=4) -> 0.23 (s=200), monotone."""
    def ratio(subset_size):
        m = SIRModel(SIRConfig(n_agents=4000, k=6,
                               subset_size=subset_size))
        tasks = m.cfg.tasks_per_step()
        costs = DESCosts(visit=3e-7, create=5e-7, erase=3e-7, enter=3e-7)
        t1 = simulate_protocol(m.des_model(), tasks,
                               config=ProtocolConfig(n_workers=1),
                               costs=costs).makespan
        t5 = simulate_protocol(m.des_model(), tasks,
                               config=ProtocolConfig(n_workers=5),
                               costs=costs).makespan
        return t5 / t1

    r_small, r_mid, r_big = ratio(4), ratio(50), ratio(200)
    assert r_big < r_mid < r_small


def test_tasks_per_cycle_limit_respected():
    # C=1 forces a creation pattern where chain can't run ahead; still
    # completes and stays shorter than with large C
    r1 = simulate_protocol(_axelrod_des(), 200,
                           config=ProtocolConfig(n_workers=2,
                                                 tasks_per_cycle=1))
    r6 = simulate_protocol(_axelrod_des(), 200,
                           config=ProtocolConfig(n_workers=2,
                                                 tasks_per_cycle=6))
    assert r1.n_tasks == r6.n_tasks == 200
    assert r1.max_chain_len <= r6.max_chain_len + 1
