"""Engine registry + single-device engine equivalences (the multi-device
sharded checks run in subprocesses — see test_engine_sharded.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_engine_matches_oracle
from repro.core import ProtocolConfig, run_engine, run_oracle, run_wavefront
from repro.engine import (
    ENGINES,
    Engine,
    SequentialEngine,
    ShardedEngine,
    WavefrontEngine,
    get_engine,
    make_engine,
)
from repro.mabs.voter import VoterModel
from repro.topology import ring, watts_strogatz


def test_registry_contents():
    assert {"sequential", "wavefront", "wavefront_overlap", "sharded",
            "sharded_replicated", "sharded_overlap"} <= set(ENGINES)
    assert get_engine("wavefront") is WavefrontEngine
    assert get_engine("sequential") is SequentialEngine
    assert get_engine("sharded") is ShardedEngine
    assert get_engine("wavefront_overlap").default_overlap
    assert get_engine("sharded_overlap").default_overlap
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("gpu-magic")


def test_make_engine_and_interface():
    m = VoterModel(ring(32, 4))
    eng = make_engine("wavefront", m, window=16)
    assert isinstance(eng, Engine)
    assert eng.window == 16
    st = m.init_state(jax.random.key(0))
    out, stats = eng.run(st, 40, seed=0)
    assert stats["total_tasks"] == 40 and stats["n_windows"] == 3
    assert out["opinions"].shape == st["opinions"].shape


@pytest.mark.parametrize("total", [64, 100])  # full windows and partial tail
def test_wavefront_engine_bitexact(total):
    m = VoterModel(watts_strogatz(64, 4, 0.2, jax.random.key(5)))
    st0 = m.init_state(jax.random.key(1))
    stats = assert_engine_matches_oracle(m, st0, total, engine="wavefront",
                                         window=32, seed=2)
    assert stats["total_waves"] >= 1


def test_run_engine_routes_by_config_and_kwarg():
    m = VoterModel(ring(32, 4))
    st0 = m.init_state(jax.random.key(0))
    cfg = ProtocolConfig(window=16, engine="sequential")
    seq, stats = run_engine(m, st0, 20, seed=0, config=cfg)
    assert stats["mean_parallelism"] == 1.0
    wf, wstats = run_engine(m, st0, 20, seed=0, config=cfg,
                            engine="wavefront")
    assert bool(jnp.all(seq["opinions"] == wf["opinions"]))
    assert wstats["mean_parallelism"] >= 1.0


def test_sharded_engine_exact_on_default_mesh():
    """The sharded engine is exact on whatever mesh the process sees —
    1 device in the tier-1 run, 8 in the multi-device CI job (its full
    multi-device sweep runs in the subprocess tests)."""
    m = VoterModel(ring(48, 4))
    st0 = m.init_state(jax.random.key(3))
    stats = assert_engine_matches_oracle(m, st0, 70, engine="sharded",
                                         window=32, seed=1)
    assert stats["n_devices"] == jax.device_count()


def test_sharded_engine_does_not_clobber_caller_state():
    """Donation must only ever touch the engine's own device_put copy."""
    m = VoterModel(ring(48, 4))
    st0 = m.init_state(jax.random.key(3))
    before = np.asarray(st0["opinions"]).copy()
    run_engine(m, st0, 64, seed=0,
               config=ProtocolConfig(window=32), engine="sharded")
    assert (np.asarray(st0["opinions"]) == before).all()
