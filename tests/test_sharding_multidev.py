"""Multi-device sharding tests — run in subprocesses with 8 host devices
(the main test process must keep the default 1-device view)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, timeout=560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout,
                       env=env)
    assert p.returncode == 0, p.stderr[-4000:]
    return p.stdout


def test_param_shardings_cover_and_divide():
    out = run_py("""
        import jax, numpy as np
        from repro.configs import ARCHS
        from repro.models.api import build_model
        from repro.distributed.sharding import params_shardings
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        for arch in ["smollm-360m", "qwen3-moe-235b-a22b", "hymba-1.5b"]:
            cfg = ARCHS[arch].reduced()
            model = build_model(cfg)
            shapes = jax.eval_shape(model.init, jax.random.key(0))
            sh = params_shardings(shapes, cfg, mesh)
            # every sharding must evenly divide its leaf
            for leaf, s in zip(jax.tree_util.tree_leaves(shapes),
                               jax.tree_util.tree_leaves(
                                   sh, is_leaf=lambda x: hasattr(x, "spec"))):
                s.shard_shape(leaf.shape)   # raises if not divisible
        print("OK")
    """)
    assert "OK" in out


def test_train_step_runs_sharded():
    out = run_py("""
        import functools, jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models.api import build_model
        from repro.train.step import (TrainHParams, init_train_state,
                                      make_train_step, train_state_shardings)
        from repro.distributed.sharding import batch_shardings
        from repro.train.data import DataConfig, SyntheticLMStream

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = ARCHS["smollm-360m"].reduced().replace(
            d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512)
        model = build_model(cfg)
        hp = TrainHParams(total_steps=10)
        step = make_train_step(model, hp)
        state = init_train_state(model, jax.random.key(0))
        shapes = jax.eval_shape(functools.partial(init_train_state, model),
                                jax.random.key(0))
        ssh = train_state_shardings(shapes, cfg, mesh)
        state = jax.device_put(state, ssh)
        stream = SyntheticLMStream(DataConfig(vocab=512, seq_len=32,
                                              global_batch=4))
        with mesh:
            fn = jax.jit(step, in_shardings=(ssh, None),
                         out_shardings=(ssh, None))
            losses = []
            for s in range(5):
                state, m = fn(state, stream.batch_at(s))
                losses.append(float(m["loss"]))
        assert all(np.isfinite(losses)), losses
        assert losses[-1] < losses[0] + 0.5
        print("OK", losses[0], losses[-1])
    """)
    assert "OK" in out


def test_sharded_equals_single_device():
    """The sharded train step must produce the same loss trajectory as the
    unsharded one (SPMD is a performance transform, not a semantic one)."""
    out = run_py("""
        import functools, jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models.api import build_model
        from repro.train.step import (TrainHParams, init_train_state,
                                      make_train_step, train_state_shardings)
        from repro.train.data import DataConfig, SyntheticLMStream

        cfg = ARCHS["smollm-360m"].reduced().replace(
            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
            n_layers=2, param_dtype="float32")
        model = build_model(cfg)
        hp = TrainHParams(total_steps=10)
        step = make_train_step(model, hp)
        stream = SyntheticLMStream(DataConfig(vocab=256, seq_len=32,
                                              global_batch=4))

        def run(sharded):
            state = init_train_state(model, jax.random.key(0))
            if sharded:
                mesh = jax.make_mesh((2, 4), ("data", "model"))
                shapes = jax.eval_shape(
                    functools.partial(init_train_state, model),
                    jax.random.key(0))
                ssh = train_state_shardings(shapes, cfg, mesh)
                state = jax.device_put(state, ssh)
                with mesh:
                    fn = jax.jit(step, in_shardings=(ssh, None),
                                 out_shardings=(ssh, None))
                    out = []
                    for s in range(4):
                        state, m = fn(state, stream.batch_at(s))
                        out.append(float(m["loss"]))
                return out
            fn = jax.jit(step)
            out = []
            for s in range(4):
                state, m = fn(state, stream.batch_at(s))
                out.append(float(m["loss"]))
            return out

        a = run(False)
        b = run(True)
        np.testing.assert_allclose(a, b, rtol=2e-4)
        print("OK", a, b)
    """)
    assert "OK" in out


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint on a (4,2) mesh, restore on (2,2) with 4 devices 'lost' —
    the elastic-rescale path (DESIGN.md §8)."""
    out = run_py(f"""
        import functools, jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models.api import build_model
        from repro.train.checkpoint import CheckpointManager
        from repro.distributed.elastic import rescale
        from repro.train.step import (TrainHParams, init_train_state,
                                      make_train_step, train_state_shardings)
        from repro.train.data import DataConfig, SyntheticLMStream

        cfg = ARCHS["smollm-360m"].reduced().replace(
            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
            n_layers=2, param_dtype="float32")
        model = build_model(cfg)
        step = make_train_step(model, TrainHParams(total_steps=20))
        stream = SyntheticLMStream(DataConfig(vocab=256, seq_len=32,
                                              global_batch=4))
        shapes = jax.eval_shape(functools.partial(init_train_state, model),
                                jax.random.key(0))

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        ssh_a = train_state_shardings(shapes, cfg, mesh_a)
        state = jax.device_put(init_train_state(model, jax.random.key(0)),
                               ssh_a)
        with mesh_a:
            fn = jax.jit(step, in_shardings=(ssh_a, None),
                         out_shardings=(ssh_a, None))
            for s in range(3):
                state, m = fn(state, stream.batch_at(s))
        loss_a = float(m["loss"])

        mgr = CheckpointManager(r"{tmp_path}")
        mgr.save(3, state, blocking=True)

        # "lose" half the devices: resume on a (2,2) mesh
        devs = np.asarray(jax.devices()[:4]).reshape(2, 2)
        mesh_b = jax.sharding.Mesh(devs, ("data", "model"))
        state_b, ssh_b, at = rescale(mgr, shapes, cfg, mesh_b)
        assert at == 3
        with mesh_b:
            fn_b = jax.jit(step, in_shardings=(ssh_b, None),
                           out_shardings=(ssh_b, None))
            state_b, m_b = fn_b(state_b, stream.batch_at(3))
        assert np.isfinite(float(m_b["loss"]))
        assert int(np.asarray(state_b.step)) == 4
        print("OK", loss_a, float(m_b["loss"]))
    """)
    assert "OK" in out


def test_decode_step_sharded():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS
        from repro.models.api import build_model
        from repro.distributed.sharding import (params_shardings,
                                                states_shardings)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = ARCHS["h2o-danube-3-4b"].reduced().replace(
            n_heads=4, n_kv_heads=4)
        model = build_model(cfg)
        params = model.init(jax.random.key(0))
        psh = params_shardings(
            jax.eval_shape(model.init, jax.random.key(0)), cfg, mesh)
        params = jax.device_put(params, psh)
        states = model.init_states(4, max_len=64)
        st_shapes = jax.eval_shape(lambda: model.init_states(4, 64))
        ssh = states_shardings(st_shapes, cfg, mesh, global_batch=4)
        states = jax.device_put(states, ssh)
        tok = jnp.ones((4, 1), jnp.int32)
        with mesh:
            logits, states = jax.jit(model.decode_step,
                                     in_shardings=(psh, None, ssh),
                                     out_shardings=(None, ssh))(
                params, tok, states)
        assert np.isfinite(np.asarray(logits)).all()
        print("OK")
    """)
    assert "OK" in out


def test_dp_layout_equals_tp_layout():
    """§Perf 'dp' layout is a sharding transform only: identical losses."""
    out = run_py("""
        import functools, jax, numpy as np
        from repro.configs import ARCHS
        from repro.models.api import build_model
        from repro.train.step import (TrainHParams, init_train_state,
                                      make_train_step, train_state_shardings)
        from repro.distributed.sharding import batch_shardings
        from repro.train.data import DataConfig, SyntheticLMStream

        base = ARCHS["rwkv6-3b"].reduced().replace(
            d_model=64, n_layers=2, vocab=256, d_ff=128,
            param_dtype="float32", head_dim=32, n_heads=2, n_kv_heads=2)
        stream = SyntheticLMStream(DataConfig(vocab=256, seq_len=32,
                                              global_batch=8))
        mesh = jax.make_mesh((2, 4), ("data", "model"))

        def run(cfg):
            model = build_model(cfg)
            step = make_train_step(model, TrainHParams(total_steps=10))
            shapes = jax.eval_shape(
                functools.partial(init_train_state, model),
                jax.random.key(0))
            ssh = train_state_shardings(shapes, cfg, mesh)
            state = jax.device_put(
                init_train_state(model, jax.random.key(0)), ssh)
            bsh = batch_shardings(
                jax.eval_shape(lambda: stream.batch_at(0)), mesh,
                layout=cfg.layout)
            with mesh:
                fn = jax.jit(step, in_shardings=(ssh, bsh),
                             out_shardings=(ssh, None))
                losses = []
                for s in range(3):
                    state, m = fn(state, stream.batch_at(s))
                    losses.append(float(m["loss"]))
            return losses

        a = run(base)                       # tp layout
        b = run(base.replace(layout="dp"))  # dp layout
        np.testing.assert_allclose(a, b, rtol=2e-4)
        print("OK", a, b)
    """)
    assert "OK" in out


def test_shard_map_moe_in_full_train_step():
    """shard_map MoE inside the scanned+rematted train step: finite loss,
    matches the dense dispatch."""
    out = run_py("""
        import dataclasses, functools, jax, numpy as np
        from repro.configs import ARCHS
        from repro.distributed.context import mesh_context
        from repro.models.api import build_model
        from repro.train.step import (TrainHParams, init_train_state,
                                      make_train_step, train_state_shardings)
        from repro.train.data import DataConfig, SyntheticLMStream

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        base = ARCHS["qwen3-moe-235b-a22b"].reduced().replace(
            param_dtype="float32")
        base = base.replace(moe=dataclasses.replace(
            base.moe, n_experts=8, top_k=2, d_expert=64,
            capacity_factor=8.0))
        stream = SyntheticLMStream(DataConfig(vocab=base.vocab, seq_len=32,
                                              global_batch=8))

        def run(cfg):
            model = build_model(cfg)
            step = make_train_step(model, TrainHParams(total_steps=10))
            shapes = jax.eval_shape(
                functools.partial(init_train_state, model),
                jax.random.key(0))
            ssh = train_state_shardings(shapes, cfg, mesh)
            state = jax.device_put(
                init_train_state(model, jax.random.key(0)), ssh)
            with mesh_context(mesh):
                fn = jax.jit(step, in_shardings=(ssh, None),
                             out_shardings=(ssh, None))
                losses = []
                for s in range(3):
                    state, m = fn(state, stream.batch_at(s))
                    losses.append(float(m["loss"]))
            return losses

        dense = run(base)
        for impl in ("shard_map", "shard_map_wg"):
            sharded = run(base.replace(moe_impl=impl))
            np.testing.assert_allclose(dense, sharded, rtol=3e-3)
        print("OK", dense)
    """, timeout=560)
    assert "OK" in out


def test_tp_shard_map_block_matches_pjit():
    """§Perf iteration 10: the manual Megatron-SP block must be numerically
    identical to the standard pjit path (incl. SWA and replicated-KV GQA)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import ARCHS
        from repro.models.api import build_model
        from repro.distributed.context import mesh_context

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        batch = {"tokens": jnp.ones((2, 32), jnp.int32),
                 "labels": jax.random.randint(jax.random.key(1), (2, 32),
                                              0, 512)}
        for name, kw in [
            ("deepseek-7b", dict(n_heads=4, n_kv_heads=4)),
            ("h2o-danube-3-4b", dict(n_heads=4, n_kv_heads=2,
                                     sliding_window=16)),
        ]:
            cfg = ARCHS[name].reduced().replace(param_dtype="float32", **kw)
            m = build_model(cfg)
            params = m.init(jax.random.key(0))
            ref, _ = jax.jit(m.loss)(params, batch)
            m2 = build_model(cfg.replace(tp_shard_map=True))
            with mesh_context(mesh):
                sp, _ = jax.jit(m2.loss)(params, batch)
                g = jax.jit(jax.grad(lambda p, b: m2.loss(p, b)[0]))(
                    params, batch)
            assert abs(float(ref) - float(sp)) < 1e-4, (name, ref, sp)
            assert all(bool(jnp.all(jnp.isfinite(l)))
                       for l in jax.tree_util.tree_leaves(g))
        print("OK")
    """, timeout=560)
    assert "OK" in out
