"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

rng = np.random.RandomState(7)


# ---------------------------------------------------------------- conflict
@pytest.mark.parametrize("w", [128, 256, 512])
@pytest.mark.parametrize("strict", [True, False])
@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_conflict_sweep(w, strict, backend):
    from repro.kernels.conflict.ops import conflict_matrix
    from repro.kernels.conflict.ref import conflict_matrix_ref

    reads = rng.randint(0, 60, size=(w, 2)).astype(np.int32)
    writes = reads[:, 1:].copy()
    valid = np.ones(w, bool)
    valid[-3:] = False
    out = conflict_matrix(reads, writes, valid, strict=strict,
                          backend=backend)
    ref = conflict_matrix_ref(jnp.asarray(reads), jnp.asarray(writes),
                              jnp.asarray(valid), strict=strict)
    assert bool(jnp.all(out == ref))


@pytest.mark.parametrize("wi,wj", [(128, 128), (96, 160), (256, 64), (7, 13)])
@pytest.mark.parametrize("strict", [True, False])
@pytest.mark.parametrize("backend", ["pallas", "jnp"])
def test_conflict_block_sweep(wi, wj, strict, backend):
    """Rectangular cross-window block (the carry-over record check):
    both backends vs the jnp oracle, including window sizes that pad up
    to the tile grid and asymmetric footprint widths."""
    from repro.kernels.conflict.ops import conflict_block
    from repro.kernels.conflict.ref import conflict_block_ref

    reads_i = rng.randint(-1, 50, size=(wi, 3)).astype(np.int32)
    writes_i = rng.randint(-1, 50, size=(wi, 1)).astype(np.int32)
    reads_j = rng.randint(-1, 50, size=(wj, 2)).astype(np.int32)
    writes_j = rng.randint(-1, 50, size=(wj, 2)).astype(np.int32)
    valid_i = rng.rand(wi) < 0.9
    valid_j = rng.rand(wj) < 0.9
    out = conflict_block(reads_i, writes_i, reads_j, writes_j,
                         valid_i, valid_j, strict=strict, backend=backend)
    ref = conflict_block_ref(
        jnp.asarray(reads_i), jnp.asarray(writes_i), jnp.asarray(reads_j),
        jnp.asarray(writes_j), jnp.asarray(valid_i), jnp.asarray(valid_j),
        strict=strict)
    assert out.shape == (wi, wj)
    assert bool(jnp.all(out == ref))


# ---------------------------------------------------------------- axelrod
@pytest.mark.parametrize("w,f", [(128, 3), (128, 100), (256, 500), (128, 128)])
def test_axelrod_kernel_sweep(w, f):
    from repro.kernels.axelrod.ops import axelrod_wave
    from repro.kernels.axelrod.ref import axelrod_wave_ref

    s = rng.randint(0, 5, (w, f)).astype(np.int32)
    t = rng.randint(0, 5, (w, f)).astype(np.int32)
    u = rng.rand(w).astype(np.float32)
    g = rng.rand(w, f).astype(np.float32)
    m = rng.rand(w) < 0.7
    new_t, inter = axelrod_wave(jnp.asarray(s), jnp.asarray(t),
                                jnp.asarray(u), jnp.asarray(g),
                                jnp.asarray(m), omega=0.95)
    fp = max(128, -(-f // 128) * 128)
    pad = lambda x: jnp.pad(jnp.asarray(x), [(0, 0), (0, fp - f)])
    rt, ri = axelrod_wave_ref(pad(s), pad(t), jnp.asarray(u), pad(g),
                              jnp.asarray(m), omega=0.95, n_features=f)
    assert bool(jnp.all(new_t == rt[:, :f]))
    assert bool(jnp.all(inter == ri))


# -------------------------------------------------------------------- sir
@pytest.mark.parametrize("w,s_sz,k", [(8, 50, 14), (16, 10, 6), (8, 400, 14),
                                      (32, 25, 2)])
def test_sir_kernel_sweep(w, s_sz, k):
    from repro.kernels.sir.ops import sir_wave
    from repro.kernels.sir.ref import sir_wave_ref

    n = 4000
    states = rng.randint(0, 3, n).astype(np.int32)
    subsets = rng.randint(0, n // s_sz, w).astype(np.int32)
    u = rng.rand(w, s_sz).astype(np.float32)
    out = sir_wave(jnp.asarray(states), jnp.asarray(subsets),
                   jnp.asarray(u), n_agents=n, k=k, subset_size=s_sz,
                   p_si=.8, p_ir=.1, p_rs=.3)
    half = k // 2
    idx = (subsets[:, None] * s_sz - half
           + np.arange(s_sz + 2 * half)[None, :]) % n
    ref = sir_wave_ref(jnp.asarray(states[idx]), jnp.asarray(u), k=k,
                       subset_size=s_sz, p_si=.8, p_ir=.1, p_rs=.3)
    assert bool(jnp.all(out == ref))


# ------------------------------------------------------------------- flash
@pytest.mark.parametrize("b,h,hkv,t,s,d,causal,window", [
    (2, 4, 2, 128, 128, 64, True, None),
    (1, 8, 2, 128, 256, 64, True, None),
    (2, 4, 2, 256, 256, 64, True, 128),
    (1, 2, 1, 128, 128, 128, False, None),
    (1, 4, 4, 256, 256, 32, True, 64),
])
def test_flash_sweep(b, h, hkv, t, s, d, causal, window):
    from repro.kernels.flash.ops import flash_attention
    from repro.kernels.flash.ref import attention_ref

    q = jnp.asarray(rng.randn(b, h, t, d).astype(np.float32) * 0.3)
    k = jnp.asarray(rng.randn(b, hkv, s, d).astype(np.float32) * 0.3)
    v = jnp.asarray(rng.randn(b, hkv, s, d).astype(np.float32) * 0.3)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_bf16():
    from repro.kernels.flash.ops import flash_attention
    from repro.kernels.flash.ref import attention_ref

    q = jnp.asarray(rng.randn(1, 4, 128, 64).astype(np.float32) * 0.3
                    ).astype(jnp.bfloat16)
    k = jnp.asarray(rng.randn(1, 2, 128, 64).astype(np.float32) * 0.3
                    ).astype(jnp.bfloat16)
    v = jnp.asarray(rng.randn(1, 2, 128, 64).astype(np.float32) * 0.3
                    ).astype(jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=0.05)


# -------------------------------------------------------------------- wkv6
@pytest.mark.parametrize("b,h,t,d", [(1, 2, 128, 64), (2, 3, 256, 64),
                                     (1, 1, 64, 128), (1, 2, 32, 64)])
def test_wkv6_sweep(b, h, t, d):
    from repro.kernels.wkv6.ops import wkv6
    from repro.kernels.wkv6.ref import wkv6_ref

    f = lambda *sh: jnp.asarray(rng.randn(*sh).astype(np.float32) * 0.4)
    r, k, v = f(b, h, t, d), f(b, h, t, d), f(b, h, t, d)
    w = jnp.exp(-jnp.exp(f(b, h, t, d)))
    u = f(h, d)
    o, sf = wkv6(r, k, v, w, u)
    oref, sref = wkv6_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=1e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sref), atol=1e-3)


def test_wkv6_chunked_jnp_matches_ref():
    from repro.kernels.wkv6.ref import wkv6_ref
    from repro.models.rwkv6 import wkv6_chunked_jnp

    b, h, t, d = 2, 2, 96, 32
    f = lambda *sh: jnp.asarray(rng.randn(*sh).astype(np.float32) * 0.4)
    r, k, v = f(b, h, t, d), f(b, h, t, d), f(b, h, t, d)
    w = jnp.exp(-jnp.exp(f(b, h, t, d)))
    u = f(h, d)
    s0 = f(b, h, d, d) * 0.1
    o, sf = wkv6_chunked_jnp(r, k, v, w, u, s0=s0, chunk=32)
    oref, sref = wkv6_ref(r, k, v, w, u, s0=s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=1e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sref), atol=1e-3)


# --------------------------------------------------------------------- ssd
def test_ssd_chunked_matches_ref():
    from repro.models.ssm import ssd_chunked, ssd_ref

    b, t, h, p, n = 2, 96, 3, 16, 8
    f = lambda *sh: jnp.asarray(rng.randn(*sh).astype(np.float32) * 0.4)
    x = f(b, t, h, p)
    dt = jnp.abs(f(b, t, h)) + 0.1
    a_log = f(h) * 0.2
    bm, cm = f(b, t, h, n), f(b, t, h, n)
    h0 = f(b, h, p, n) * 0.1
    y, s = ssd_chunked(x, dt, a_log, bm, cm, h0=h0, chunk=32)
    yr, sr = ssd_ref(x, dt, a_log, bm, cm, h0=h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=2e-3)


def test_ssd_decode_step_matches_ref():
    from repro.models.ssm import ssd_decode_step, ssd_ref

    b, h, p, n = 2, 3, 16, 8
    f = lambda *sh: jnp.asarray(rng.randn(*sh).astype(np.float32) * 0.4)
    x = f(b, 1, h, p)
    dt = jnp.abs(f(b, 1, h)) + 0.1
    a_log = f(h) * 0.2
    bm, cm = f(b, 1, h, n), f(b, 1, h, n)
    h0 = f(b, h, p, n) * 0.1
    yr, sr = ssd_ref(x, dt, a_log, bm, cm, h0=h0)
    y, s = ssd_decode_step(h0, x[:, 0], dt[:, 0], a_log, bm[:, 0], cm[:, 0])
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr[:, 0]),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), atol=2e-4)
