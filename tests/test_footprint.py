"""Footprint protocol: derived conflicts == hand-written predicates, and
all three conflict-matrix implementations (broadcast predicate, jnp
fallback, Pallas kernel) agree on the same windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import footprint_conflicts, prefix_conflicts, window_conflicts
from repro.kernels.conflict.ops import conflict_matrix, conflict_matrix_jnp
from repro.kernels.conflict.ref import conflict_matrix_ref
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
from repro.mabs.sir import SIRConfig, SIRModel
from repro.mabs.sis import SISModel
from repro.mabs.voter import VoterModel
from repro.topology import erdos_renyi, ring, watts_strogatz


def _axelrod_models():
    topo = watts_strogatz(40, 4, 0.25, jax.random.key(5))
    return [
        ("complete", AxelrodModel(AxelrodConfig(n_agents=40, n_features=3))),
        ("ws", AxelrodModel(AxelrodConfig(n_agents=40, n_features=3),
                            topology=topo)),
    ]


def _sir_models():
    er = erdos_renyi(120, 0.04, jax.random.key(6))
    cfg = SIRConfig(n_agents=120, k=6, subset_size=10, i0=0.3)
    return [
        ("ring", SIRModel(cfg)),
        ("er", SIRModel(cfg, topology=er)),
    ]


@pytest.mark.parametrize("name,model",
                         _axelrod_models() + _sir_models())
@pytest.mark.parametrize("strict", [True, False])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_footprint_identical_to_handwritten(name, model, strict, seed):
    """The footprint-derived rule must reproduce the hand-written
    ``conflicts`` predicate EXACTLY — strict and paper rules — on both
    seed scenarios, over full and padded windows."""
    w = 64
    recipes = model.create_tasks(jax.random.key(seed), seed * w, w)
    rng = np.random.RandomState(seed)
    valid = jnp.asarray(rng.rand(w) < 0.9)

    hand = prefix_conflicts(model.conflicts, recipes, valid, strict=strict)

    # 1) derived pairwise predicate == hand-written predicate
    rows = jax.tree_util.tree_map(lambda x: x[:, None], recipes)
    cols = jax.tree_util.tree_map(lambda x: x[None, :], recipes)
    derived = footprint_conflicts(model.task_footprint(rows),
                                  model.task_footprint(cols), strict=strict)
    lower = jnp.tril(jnp.ones((w, w), bool), k=-1)
    derived = derived & lower & valid[:, None] & valid[None, :]
    assert bool(jnp.all(derived == hand))

    # 2) the kernel-path matrix (what the engine actually schedules with)
    reads, writes = model.task_footprint(recipes)
    for backend in ("jnp", "pallas"):
        got = conflict_matrix(reads, writes, valid, strict=strict,
                              backend=backend)
        assert bool(jnp.all(got == hand)), backend

    # 3) and the engine's own router picks the footprint path
    routed = window_conflicts(model, recipes, valid, strict=strict)
    assert bool(jnp.all(routed == hand))


@pytest.mark.parametrize("strict", [True, False])
def test_new_models_inherit_footprint_conflicts(strict):
    """Voter/SIS have no hand-written predicate: MABSModel.conflicts must
    come from their footprints and agree with the reference oracle."""
    topo = ring(50, 4)
    for model in (VoterModel(topo), SISModel(topo)):
        w = 48
        recipes = model.create_tasks(jax.random.key(0), 0, w)
        valid = jnp.ones(w, bool)
        via_predicate = prefix_conflicts(model.conflicts, recipes, valid,
                                         strict=strict)
        reads, writes = model.task_footprint(recipes)
        ref = conflict_matrix_ref(reads, writes, valid, strict=strict)
        assert bool(jnp.all(via_predicate == ref))


@pytest.mark.parametrize("w", [17, 100, 130, 300])
@pytest.mark.parametrize("strict", [True, False])
def test_pallas_pad_to_block(w, strict):
    """Windows that are not a multiple of the 128 tile must pad internally
    and match both the jnp fallback and the reference."""
    rng = np.random.RandomState(w)
    reads = rng.randint(-1, 30, size=(w, 3)).astype(np.int32)
    writes = rng.randint(-1, 30, size=(w, 2)).astype(np.int32)
    valid = jnp.asarray(rng.rand(w) < 0.9)
    pal = conflict_matrix(reads, writes, valid, strict=strict,
                          backend="pallas")
    jnp_ = conflict_matrix_jnp(jnp.asarray(reads), jnp.asarray(writes),
                               valid, strict=strict)
    ref = conflict_matrix_ref(jnp.asarray(reads), jnp.asarray(writes),
                              valid, strict=strict)
    assert pal.shape == (w, w)
    assert bool(jnp.all(pal == ref))
    assert bool(jnp.all(jnp_ == ref))


def test_paper_rule_is_flow_only():
    """Non-strict = RAW: a pure write/write or write/read collision must
    not conflict under the paper's record rule but must under strict."""
    reads = jnp.asarray([[0], [1]], jnp.int32)   # task0 reads 0, task1 reads 1
    writes = jnp.asarray([[7], [7]], jnp.int32)  # both write 7 (WAW only)
    valid = jnp.ones(2, bool)
    assert not bool(conflict_matrix_ref(reads, writes, valid,
                                        strict=False)[1, 0])
    assert bool(conflict_matrix_ref(reads, writes, valid, strict=True)[1, 0])
    # WAR: task1 writes what task0 reads
    reads = jnp.asarray([[3], [-1]], jnp.int32)
    writes = jnp.asarray([[9], [3]], jnp.int32)
    assert not bool(conflict_matrix_ref(reads, writes, valid,
                                        strict=False)[1, 0])
    assert bool(conflict_matrix_ref(reads, writes, valid, strict=True)[1, 0])
