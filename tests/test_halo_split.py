"""Unit tests for the per-wave halo-split builders
(``distributed.sharding.wave_halo_split`` / ``wave_halo_gather`` /
``wave_slab_counts``) — pure-jnp layout checks plus the zero-width /
empty-wave no-op contract. These run in-process on the default single
device (``halo_gather`` degenerates to a self-psum on a 1-device mesh);
the multi-device behavior is covered end to end by the engine and
differential suites."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import (
    AGENT_AXIS,
    halo_gather,
    wave_halo_gather,
    wave_halo_split,
    wave_slab_counts,
)
from repro.utils.compat import shard_map


def _slab_rows(slabs, chunk_start, w):
    """Host-side: the valid rows of wave w's chunk range."""
    c0, c1 = int(chunk_start[w]), int(chunk_start[w + 1])
    rows = np.asarray(slabs)[c0:c1].reshape(-1)
    return rows[rows >= 0]


def test_split_layout_partitions_rows_by_wave():
    """Every valid row lands in exactly its task's wave slab, waves own
    disjoint chunk ranges, and padding is -1."""
    rows = jnp.asarray([[3, 7], [1, -1], [5, 6], [2, 7], [-1, -1]],
                       dtype=jnp.int32)
    levels = jnp.asarray([0, 1, 0, 2, 1], dtype=jnp.int32)
    slabs, chunk_start = wave_halo_split(rows, levels, n_waves_max=5,
                                         chunk=3)
    counts = wave_slab_counts(rows, levels, n_waves_max=5)
    assert counts.tolist() == [4, 1, 2, 0, 0]
    # chunk ranges: ceil(4/3)=2, ceil(1/3)=1, ceil(2/3)=1, 0, 0
    assert chunk_start.tolist() == [0, 2, 3, 4, 4, 4]
    assert sorted(_slab_rows(slabs, chunk_start, 0)) == [3, 5, 6, 7]
    assert sorted(_slab_rows(slabs, chunk_start, 1)) == [1]
    assert sorted(_slab_rows(slabs, chunk_start, 2)) == [2, 7]
    # everything past the allocated chunks is padding
    assert bool(jnp.all(slabs[int(chunk_start[-1]):] == -1))


def test_split_drops_invalid_tasks_and_rows():
    """Level -1 (executed/invalid) tasks and -1 row slots contribute
    nothing; levels >= n_waves_max (an overlapped pair's beyond-horizon
    tasks) are dropped rather than scattered."""
    rows = jnp.asarray([[4, 4], [9, 2], [8, -1]], dtype=jnp.int32)
    levels = jnp.asarray([-1, 7, 1], dtype=jnp.int32)
    slabs, chunk_start = wave_halo_split(rows, levels, n_waves_max=2,
                                         chunk=2)
    counts = wave_slab_counts(rows, levels, n_waves_max=2)
    assert counts.tolist() == [0, 1]
    assert chunk_start.tolist() == [0, 0, 1]
    assert _slab_rows(slabs, chunk_start, 1).tolist() == [8]


def test_empty_wave_owns_no_chunks():
    """A fully-drained wave (level gap after rebasing in overlapped
    mode) owns a zero-width chunk range — the executor's chunk loop
    body never runs, so no collective is issued for it."""
    rows = jnp.asarray([[0, 1], [2, 3]], dtype=jnp.int32)
    levels = jnp.asarray([0, 2], dtype=jnp.int32)  # wave 1 is empty
    slabs, chunk_start = wave_halo_split(rows, levels, n_waves_max=4,
                                         chunk=8)
    assert chunk_start.tolist() == [0, 1, 1, 2, 2]
    assert int(chunk_start[2]) - int(chunk_start[1]) == 0  # wave 1: no-op


def test_counts_bound_by_total_valid_rows():
    rng = np.random.RandomState(0)
    rows = jnp.asarray(rng.randint(-1, 50, size=(32, 4)), dtype=jnp.int32)
    levels = jnp.asarray(rng.randint(-1, 10, size=(32,)), dtype=jnp.int32)
    counts = wave_slab_counts(rows, levels, n_waves_max=32)
    n_valid = int(jnp.sum((rows >= 0) & (levels[:, None] >= 0)))
    assert int(jnp.sum(counts)) == n_valid


def test_zero_width_gather_is_a_clean_noop():
    """``halo_gather`` on a zero-width halo (and ``wave_halo_gather`` on
    zero-width chunks) must return an empty result without materializing
    a degenerate collective."""
    mesh = Mesh(np.asarray(jax.devices()[:1]), (AGENT_AXIS,))
    local = jnp.arange(12, dtype=jnp.float32).reshape(6, 2)
    empty_halo = jnp.zeros((0,), jnp.int32)
    slabs0 = jnp.zeros((3, 0), jnp.int32)   # chunked layout, width 0

    def f(loc):
        g = halo_gather(loc, empty_halo, shard_n=6)
        gc, slab = wave_halo_gather(loc, slabs0, jnp.int32(1), shard_n=6)
        return g, gc, slab

    g, gc, slab = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(AGENT_AXIS),),
        out_specs=(P(), P(), P()), check_vma=False))(local)
    assert g.shape == (0, 2) and gc.shape == (0, 2) and slab.shape == (0,)


def test_gather_matches_monolithic_on_one_device():
    """Gathering a wave's chunks one by one delivers exactly the same
    rows as a monolithic gather of that wave's slab."""
    mesh = Mesh(np.asarray(jax.devices()[:1]), (AGENT_AXIS,))
    state = jnp.arange(20, dtype=jnp.float32)
    rows = jnp.asarray([[3, 17], [5, -1], [11, 3]], dtype=jnp.int32)
    levels = jnp.asarray([0, 1, 0], dtype=jnp.int32)
    slabs, chunk_start = wave_halo_split(rows, levels, n_waves_max=3,
                                         chunk=2)

    def f(loc):
        out = jnp.zeros((20,), jnp.float32)
        c0, c1 = chunk_start[0], chunk_start[1]

        def body(carry):
            c, acc = carry
            g, slab = wave_halo_gather(loc, slabs, c, shard_n=20)
            acc = acc.at[jnp.where(slab >= 0, slab, 20)].set(
                g, mode="drop")
            return c + 1, acc

        _, out = jax.lax.while_loop(lambda c: c[0] < c1, body, (c0, out))
        return out

    out = jax.jit(shard_map(f, mesh=mesh, in_specs=(P(AGENT_AXIS),),
                            out_specs=P(), check_vma=False))(state)
    expect = np.zeros(20, np.float32)
    for r in (3, 17, 11):   # wave 0's rows
        expect[r] = float(state[r])
    np.testing.assert_array_equal(np.asarray(out), expect)
