"""HLO-analysis unit tests (multi-device parts run in subprocesses)."""
import os
import subprocess
import sys
import textwrap

from repro.launch.hlo_analysis import (
    _shape_bytes,
    parse_computations,
    trip_count,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_shape_bytes():
    assert _shape_bytes("f32[64,512]{1,0}") == 64 * 512 * 4
    assert _shape_bytes("bf16[2,3]") == 12
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert _shape_bytes("pred[]") == 1


def test_trip_count_from_condition():
    lines = ["%c = s32[] constant(94)",
             "%cmp = pred[] compare(%i, %c), direction=LT"]
    assert trip_count(lines) == 94
    assert trip_count(["nothing here"]) is None


def test_collective_analysis_with_scan():
    """End-to-end on a real lowered program: collectives inside a scanned
    body must be multiplied by the recovered trip count."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_collectives

        mesh = jax.make_mesh((2, 4), ("data", "model"))

        def f(ws, x):
            def layer(x, w):
                return jnp.tanh(x @ w), None
            y, _ = jax.lax.scan(layer, x, ws)
            return jnp.sum(y)

        ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
        x = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        with mesh:
            c = jax.jit(
                f,
                in_shardings=(NamedSharding(mesh, P(None, None, "model")),
                              NamedSharding(mesh, P("data", None))),
                out_shardings=NamedSharding(mesh, P()),
            ).lower(ws, x).compile()
        st = analyze_collectives(c.as_text())
        # the in-loop reduction must appear with multiplier ~7
        loop = sum(st.loop_bytes.values())
        raw = sum(st.raw_bytes.values())
        assert loop > raw, (st.loop_bytes, st.raw_bytes)
        assert st.unknown_trip_whiles == 0
        print("OK", st.count, loop / max(raw, 1))
    """)
    p = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=560, env=env)
    assert p.returncode == 0, p.stderr[-3000:]
    assert "OK" in p.stdout


def test_roofline_analytic_costs():
    from benchmarks.roofline import analytic_costs

    rec = {"arch": "smollm-360m", "shape": "train_4k", "mesh": "single",
           "kind": "train", "seq_len": 4096, "global_batch": 256}
    an = analytic_costs(rec)
    # 6·N·D sanity: 6 × 0.36e9 params × (256·4096 ≈ 1.05e6 tokens) ≈ 2.3e15
    assert 1e15 < an["model_flops"] < 1e16
    assert an["flops_analytic"] >= an["model_flops"]

    rec2 = {"arch": "rwkv6-3b", "shape": "decode_32k", "mesh": "single",
            "kind": "decode", "seq_len": 32768, "global_batch": 128}
    an2 = analytic_costs(rec2)
    assert an2["flops_analytic"] > 0 and an2["bytes_analytic"] > 0


def test_input_specs_cover_all_cells():
    import jax

    from repro.configs import ARCHS, SHAPES, applicable
    from repro.models.api import input_specs

    n_run, n_skip = 0, 0
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            ok, reason = applicable(cfg, shape)
            if not ok:
                n_skip += 1
                assert reason
                continue
            n_run += 1
            specs = input_specs(cfg, shape)
            for leaf in jax.tree_util.tree_leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert n_run + n_skip == 40
    assert n_skip == 7  # full-attention archs skip long_500k
