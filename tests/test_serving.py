"""Serving engine: protocol-scheduled continuous batching correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine


def _sequential(model, params, prompt, max_new, max_len=64):
    states = model.init_states(1, max_len=max_len)
    lp, states = jax.jit(model.prefill)(
        params, {"tokens": jnp.asarray(prompt)[None]}, states)
    toks = [int(jnp.argmax(lp[0]))]
    for _ in range(max_new - 1):
        ld, states = jax.jit(model.decode_step)(
            params, jnp.asarray([[toks[-1]]], jnp.int32), states)
        toks.append(int(jnp.argmax(ld[0])))
    return toks


@pytest.mark.parametrize("arch", ["smollm-360m", "rwkv6-3b", "hymba-1.5b"])
def test_engine_matches_sequential(arch):
    cfg = ARCHS[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab, size=n).astype(np.int32)
               for n in (5, 9, 17, 3)]
    refs = [_sequential(model, params, p, 6) for p in prompts]
    eng = ServingEngine(model, params, n_slots=3, max_len=64,
                        prefill_chunk=8)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=6))
    done = sorted(eng.run(), key=lambda r: r.rid)
    for req, ref in zip(done, refs):
        assert req.out_tokens == ref, (arch, req.rid)


def test_engine_mid_flight_arrival():
    """Bottom-up asynchrony: a request submitted while others decode joins
    the running waves without disturbing their outputs."""
    cfg = ARCHS["smollm-360m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(1)
    p0 = rng.randint(0, cfg.vocab, size=6).astype(np.int32)
    p1 = rng.randint(0, cfg.vocab, size=4).astype(np.int32)
    ref0 = _sequential(model, params, p0, 8)
    ref1 = _sequential(model, params, p1, 5)

    eng = ServingEngine(model, params, n_slots=2, max_len=64,
                        prefill_chunk=8)
    eng.submit(Request(rid=0, prompt=p0, max_new_tokens=8))
    for _ in range(3):
        eng.step()
    eng.submit(Request(rid=1, prompt=p1, max_new_tokens=5))  # mid-flight
    done = sorted(eng.run(), key=lambda r: r.rid)
    assert done[0].out_tokens == ref0
    assert done[1].out_tokens == ref1


def test_engine_chunked_prefill_straggler():
    """A long prompt must not serialize the batch: with chunked prefill the
    short request finishes during the long request's prefill window."""
    cfg = ARCHS["smollm-360m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(2)
    long_p = rng.randint(0, cfg.vocab, size=40).astype(np.int32)
    short_p = rng.randint(0, cfg.vocab, size=4).astype(np.int32)
    eng = ServingEngine(model, params, n_slots=2, max_len=96,
                        prefill_chunk=4)  # 10 chunks for the long prompt
    eng.submit(Request(rid=0, prompt=long_p, max_new_tokens=2))
    eng.submit(Request(rid=1, prompt=short_p, max_new_tokens=3))
    eng.run()
    # short request must have finished before the long one
    order = [r.rid for r in eng.finished]
    assert order[0] == 1
    # waves mixed prefill + decode (adaptive heterogeneous execution)
    assert max(eng.wave_sizes) >= 2


def test_engine_eos_and_slot_reuse():
    cfg = ARCHS["smollm-360m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.RandomState(3)
    reqs = [Request(rid=i,
                    prompt=rng.randint(0, cfg.vocab, size=5).astype(np.int32),
                    max_new_tokens=4)
            for i in range(6)]
    eng = ServingEngine(model, params, n_slots=2, max_len=64,
                        prefill_chunk=8)
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 6
    assert all(len(r.out_tokens) == 4 for r in done)
