"""Sparse edge-list topology path: from_edges vs from_adjacency
equivalence across every generator, dense-helper guards, and the
large-N construction + scheduling smoke tests (10^5 runs in the CI
large-N job, 10^6 is the acceptance bar for the builders)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.topology import (
    DENSE_LIMIT,
    PAD,
    Topology,
    barabasi_albert,
    complete,
    erdos_renyi,
    from_adjacency,
    from_edges,
    lattice2d,
    ring,
    watts_strogatz,
)

KEY = jax.random.key(42)


def _densify(n, edges, valid=None, *, self_loops=False):
    """Host-side reference: scatter an edge list into a dense adjacency."""
    adj = np.zeros((n, n), dtype=bool)
    e = np.asarray(edges)
    ok = (e >= 0).all(axis=1) & (e < n).all(axis=1)
    if valid is not None:
        ok &= np.asarray(valid)
    for u, v in e[ok]:
        if u == v and not self_loops:
            continue
        adj[u, v] = adj[v, u] = True
    return adj


def _assert_same(a: Topology, b: Topology):
    assert bool(jnp.all(a.degrees == b.degrees))
    w = min(a.max_degree, b.max_degree)
    assert bool(jnp.all(a.neighbors[:, :w] == b.neighbors[:, :w]))
    # any extra slots past the common width must be padding
    if a.max_degree > w:
        assert bool(jnp.all(a.neighbors[:, w:] == PAD))
    if b.max_degree > w:
        assert bool(jnp.all(b.neighbors[:, w:] == PAD))


def test_from_edges_matches_from_adjacency():
    """Same edge set through both builders -> identical padded CSR
    (packing order, padding, degrees)."""
    rng = np.random.RandomState(0)
    n, e = 50, 200
    edges = rng.randint(0, n, size=(e, 2)).astype(np.int32)
    sparse = from_edges(n, jnp.asarray(edges))
    dense = from_adjacency(jnp.asarray(_densify(n, edges)))
    _assert_same(sparse, dense)


def test_from_edges_valid_mask_and_negatives():
    edges = jnp.asarray([[0, 1], [1, 2], [-1, 3], [2, 7], [3, 3]],
                        dtype=jnp.int32)
    valid = jnp.asarray([True, False, True, True, True])
    t = from_edges(5, edges, valid=valid)  # keeps (0,1), (3,3) self-dropped
    # (1,2) masked, (-1,3) negative, (2,7) out of range, (3,3) self loop
    assert np.asarray(t.degrees).tolist() == [1, 1, 0, 0, 0]
    assert int(t.neighbors[0, 0]) == 1 and int(t.neighbors[1, 0]) == 0


def test_from_edges_self_loops_and_duplicates():
    edges = jnp.asarray([[0, 1], [1, 0], [0, 1], [2, 2]], dtype=jnp.int32)
    t = from_edges(3, edges, allow_self_loops=True)
    assert np.asarray(t.degrees).tolist() == [1, 1, 1]
    assert int(t.neighbors[2, 0]) == 2  # self loop kept once


def test_from_edges_host_radix_matches_traced_lexsort():
    """The concrete (eager) build takes the bucketed by-source counting
    sort on the host; traced builds keep the jnp lexsort. The two orders
    must be interchangeable: identical Topology for the same edge set,
    including ties (duplicate edges, both directions, invalid slots)."""
    rng = np.random.RandomState(3)
    n, e = 60, 400
    edges = jnp.asarray(rng.randint(-2, n + 2, size=(e, 2)).astype(np.int32))
    valid = jnp.asarray(rng.rand(e) < 0.8)
    eager = from_edges(n, edges, valid=valid, max_degree=16)
    jitted = jax.jit(
        lambda ed, va: from_edges(n, ed, valid=va, max_degree=16))(
            edges, valid)
    assert bool(jnp.all(eager.neighbors == jitted.neighbors))
    assert bool(jnp.all(eager.degrees == jitted.degrees))


def test_from_edges_max_degree_clamp():
    """Rows past the static bound keep their lowest-id neighbors, same as
    from_adjacency."""
    edges = jnp.asarray([[0, 3], [0, 1], [0, 4], [0, 2]], dtype=jnp.int32)
    t = from_edges(5, edges, max_degree=2)
    d = from_adjacency(jnp.asarray(_densify(5, edges)), max_degree=2)
    assert bool(jnp.all(t.neighbors == d.neighbors))
    assert bool(jnp.all(t.degrees == d.degrees))
    assert np.asarray(t.neighbors[0]).tolist() == [1, 2]


@pytest.mark.parametrize("name,build", [
    ("ring", lambda: ring(40, 6)),
    ("lattice2d", lambda: lattice2d(5, 8)),
    ("watts_strogatz", lambda: watts_strogatz(40, 4, 0.3, KEY)),
    ("erdos_renyi", lambda: erdos_renyi(40, 0.15, KEY)),
    ("barabasi_albert", lambda: barabasi_albert(40, 2, KEY)),
])
def test_generators_sparse_dense_equivalence(name, build):
    """Every generator's sparse build equals the dense from_adjacency
    compaction of its own edge set, and the same seed reproduces the
    identical edge set."""
    topo = build()
    edges, valid = topo.edge_list()
    dense = from_adjacency(jnp.asarray(
        _densify(topo.n_nodes, edges, valid)))
    _assert_same(topo, dense)
    again = build()
    assert bool(jnp.all(topo.neighbors == again.neighbors))
    assert bool(jnp.all(topo.degrees == again.degrees))


def test_barabasi_albert_chunked_equivalence():
    """Builder-equivalence regression for the chunked attachment fast
    path: chunk=1 freezes the endpoint multiset at every single arrival,
    which is exactly what the sequential scan does — the two must be
    bit-identical. A chunk larger than the arrival count degenerates to
    the pure warm-up (sequential) prefix and must also be identical."""
    for n, m in ((64, 2), (200, 3)):
        key = jax.random.fold_in(KEY, n)
        seq = barabasi_albert(n, m, key)
        for chunk in (1, 10 * n):
            fast = barabasi_albert(n, m, key, chunk=chunk)
            assert bool(jnp.all(seq.neighbors == fast.neighbors)), (n, chunk)
            assert bool(jnp.all(seq.degrees == fast.degrees)), (n, chunk)


def test_barabasi_albert_chunked_structure():
    """chunk > 1 changes the realization (degrees lag by up to a block)
    but must still produce a valid BA-shaped simple graph: exactly m
    edges per arrival plus the complete seed, no self loops, no
    duplicate neighbors, every node attached."""
    n, m, chunk = 300, 3, 32
    topo = barabasi_albert(n, m, jax.random.fold_in(KEY, 7), chunk=chunk)
    seed_sz = m + 1
    expected_edges = seed_sz * (seed_sz - 1) // 2 + (n - seed_sz) * m
    assert int(topo.n_edges) == expected_edges
    nbrs, deg = np.asarray(topo.neighbors), np.asarray(topo.degrees)
    assert deg.min() >= m
    for v in range(n):
        row = nbrs[v][: deg[v]]
        assert v not in row
        assert len(set(row.tolist())) == deg[v]
        assert (nbrs[v][deg[v]:] == PAD).all()


def test_adjacency_guard_above_dense_limit():
    t = ring(DENSE_LIMIT + 2, 2)
    with pytest.raises(ValueError, match="dense"):
        t.adjacency()
    with pytest.raises(ValueError, match="dense"):
        complete(DENSE_LIMIT + 2)
    # at the limit the dense helpers still work
    assert ring(16, 2).adjacency().shape == (16, 16)


def test_block_graph_stays_sparse_above_dense_limit():
    """block_graph used to densify through [m, m]; it must now work when
    the block count itself exceeds the dense guard."""
    m = DENSE_LIMIT + 4  # block count > DENSE_LIMIT
    t = ring(2 * m, 4)
    bg = t.block_graph(2)
    assert bg.n_nodes == m
    # ring blocks: self loop + both circular neighbors
    assert int(bg.degrees[0]) == 3
    row = set(np.asarray(bg.neighbors[5]).tolist())
    assert {4, 5, 6} <= row


def test_large_n_smoke():
    """CI large-N job: a 10^5-node sparse Watts-Strogatz graph, one
    window scheduled and executed through the wavefront engine on CPU."""
    from repro.core import ProtocolConfig, run_wavefront
    from repro.mabs.sis import SISModel

    n = 100_000
    topo = watts_strogatz(n, 4, 0.1, jax.random.key(0))
    # rewires that land on existing edges drop (simple-graph variant)
    assert topo.n_nodes == n and 2 * n - 64 <= int(topo.n_edges) <= 2 * n
    m = SISModel(topo)
    st0 = m.init_state(jax.random.key(1))
    out, stats = run_wavefront(m, st0, 256, seed=2,
                               config=ProtocolConfig(window=256))
    assert stats["total_tasks"] == 256 and stats["total_waves"] >= 1
    assert out["states"].shape == (n,)


def test_million_node_construction_and_scheduling():
    """The acceptance bar: 10^6-node ring and Watts-Strogatz build on CPU
    (no [n, n] anywhere — the guard would refuse it), and a window of
    voter tasks schedules on the result."""
    from repro.core.records import wave_levels, window_conflicts
    from repro.mabs.voter import VoterModel

    n = 1_000_000
    r = ring(n, 4)
    assert r.neighbors.shape == (n, 4)
    assert int(r.degrees.min()) == int(r.degrees.max()) == 4

    ws = watts_strogatz(n, 4, 0.1, jax.random.key(7))
    # rewires that land on existing edges drop (simple-graph variant)
    assert ws.n_nodes == n and 2 * n - 256 <= int(ws.n_edges) <= 2 * n
    assert int(ws.degrees.min()) >= 0 and int(ws.degrees.max()) < 64

    # WS sources keep their clockwise edges, so min degree >= k/2 >= 1
    model = VoterModel(ws)
    recipes = model.create_tasks(jax.random.key(3), 0, 128)
    valid = jnp.ones((128,), bool)
    conf = window_conflicts(model, recipes, valid, strict=True)
    levels = wave_levels(conf, valid)
    assert int(levels.max()) >= 0 and int(levels.max()) < 128
    assert bool(jnp.all(levels >= 0))
