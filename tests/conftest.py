"""Shared fixtures and assertion helpers. NOTE: no XLA_FLAGS device-count
override here — smoke tests and benches must see the real single CPU
device; only the dry-run (and the subprocess-based sharding tests) force
placeholder devices.

``BASE_SEED`` (env ``MABS_TEST_SEED``, default 0) offsets every seeded
sweep in the differential harness; CI runs the tier-1 suite under two
distinct values to catch seed-dependent schedule bugs (a wave order that
only breaks for particular conflict draws).

The engine assertion helpers live here (plain functions, importable as
``from conftest import ...`` whenever the tests directory is on the
path — the subprocess-based multi-device tests add it) so the
differential harness and the existing engine tests share one definition
of "bit-exact vs the oracle" and one definition of sane overlap stats.
"""
import os

import jax
import pytest

BASE_SEED = int(os.environ.get("MABS_TEST_SEED", "0"))


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)


@pytest.fixture(scope="session")
def base_seed():
    return BASE_SEED


def assert_engine_matches_oracle(model, state0, total, *, engine,
                                 window=32, strict=True, seed=0,
                                 oracle_state=None, **engine_kwargs):
    """Run ``total`` tasks through ``engine`` and assert every state leaf
    is bit-identical to the sequential oracle; returns the engine stats.

    ``engine`` is a registry name or a prebuilt Engine instance (the
    differential harness reuses instances across totals to amortize
    compilation). Pass ``oracle_state`` to reuse a precomputed oracle
    result (the harness runs many engines against one oracle run).
    """
    import jax.numpy as jnp

    from repro.core import ProtocolConfig, run_engine, run_oracle

    cfg = ProtocolConfig(window=window, strict=strict)
    if isinstance(engine, str):
        out, stats = run_engine(model, state0, total, seed=seed, config=cfg,
                                engine=engine, **engine_kwargs)
    else:
        out, stats = engine.run(state0, total, seed=seed)
        engine = engine.name  # for the assertion message below
    if oracle_state is None:
        oracle_state = run_oracle(model, state0, total, seed=seed,
                                  config=cfg)
    flat_o, _ = jax.tree_util.tree_flatten_with_path(oracle_state)
    flat_e = jax.tree_util.tree_leaves(out)
    assert len(flat_o) == len(flat_e)
    for (path, ref), got in zip(flat_o, flat_e):
        assert bool(jnp.all(got == ref)), (
            f"engine {engine!r} diverged from the oracle on leaf "
            f"{jax.tree_util.keystr(path)} (total={total}, window={window}, "
            f"seed={seed})")
    return stats


def assert_overlap_stats_monotone(stats, *, window, barrier_stats=None):
    """Sanity envelope for the overlapped engines' carry-over stats:
    depths are bounded by the window's wave count, counters are
    non-negative and mutually consistent, and — when the matching
    barrier run is provided — overlap never *increases* the executed
    wave count (the monotone-improvement guarantee: fused waves strictly
    merge the barrier schedule, task for task)."""
    assert stats.get("overlap") is True
    assert stats["n_boundaries"] == max(stats["n_windows"] - 1, 0)
    assert 0 <= stats["mean_overlap_depth"] <= window
    assert 0 <= stats["max_overlap_depth"] <= window
    assert stats["mean_overlap_depth"] <= stats["max_overlap_depth"] or (
        stats["n_boundaries"] == 0)
    assert stats["overlap_tasks_early"] >= 0
    assert stats["overlap_tasks_early"] <= stats["total_tasks"]
    assert 0 <= stats["carry_frontier_mean"] <= stats["carry_frontier_max"] \
        or stats["n_boundaries"] == 0
    assert stats["carry_frontier_max"] <= window
    if stats["max_overlap_depth"] == 0:
        assert stats["overlap_tasks_early"] == 0
    if barrier_stats is not None:
        assert stats["total_waves"] <= barrier_stats["total_waves"], (
            "overlapped run executed more waves than the barrier run")
        assert stats["total_tasks"] == barrier_stats["total_tasks"]
