"""Shared fixtures. NOTE: no XLA_FLAGS device-count override here — smoke
tests and benches must see the real single CPU device; only the dry-run
(and the subprocess-based sharding tests) force placeholder devices."""
import jax
import pytest


@pytest.fixture(scope="session")
def key():
    return jax.random.key(0)
