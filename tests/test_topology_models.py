"""New scenarios (voter, SIS) + topology-ported seed scenarios: wavefront
execution must equal the sequential oracle bit-exactly under the strict
rule on every contact network (the acceptance bar for the subsystem)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ProtocolConfig, run_oracle, run_wavefront
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel
from repro.mabs.sir import SIRConfig, SIRModel
from repro.mabs.sis import SISConfig, SISModel
from repro.mabs.voter import VoterConfig, VoterModel
from repro.topology import erdos_renyi, lattice2d, ring, watts_strogatz

N = 60


def _topologies():
    return [
        ("ring", ring(N, 4)),
        ("lattice", lattice2d(6, 10, neighborhood="von_neumann")),
        ("watts_strogatz", watts_strogatz(N, 4, 0.3, jax.random.key(8))),
    ]


@pytest.mark.parametrize("tname,topo", _topologies())
@pytest.mark.parametrize("seed", [0, 1])
def test_voter_wavefront_bitexact(tname, topo, seed):
    m = VoterModel(topo, VoterConfig(n_opinions=3))
    st0 = m.init_state(jax.random.key(seed))
    cfg = ProtocolConfig(window=48, strict=True)
    w, _ = run_wavefront(m, st0, 300, seed=seed, config=cfg)
    s = run_oracle(m, st0, 300, seed=seed, config=cfg)
    assert bool(jnp.all(w["opinions"] == s["opinions"]))


@pytest.mark.parametrize("tname,topo", _topologies())
@pytest.mark.parametrize("seed", [0, 1])
def test_sis_wavefront_bitexact(tname, topo, seed):
    m = SISModel(topo, SISConfig(i0=0.3))
    st0 = m.init_state(jax.random.key(seed))
    cfg = ProtocolConfig(window=48, strict=True)
    w, _ = run_wavefront(m, st0, 300, seed=seed, config=cfg)
    s = run_oracle(m, st0, 300, seed=seed, config=cfg)
    assert bool(jnp.all(w["states"] == s["states"]))


@pytest.mark.parametrize("tname,topo", _topologies())
def test_axelrod_network_restricted_bitexact(tname, topo):
    m = AxelrodModel(AxelrodConfig(n_agents=N, n_features=3, q=3),
                     topology=topo)
    st0 = m.init_state(jax.random.key(0))
    cfg = ProtocolConfig(window=48, strict=True)
    w, _ = run_wavefront(m, st0, 250, seed=2, config=cfg)
    s = run_oracle(m, st0, 250, seed=2, config=cfg)
    assert bool(jnp.all(w["traits"] == s["traits"]))


def test_axelrod_partners_are_neighbors():
    topo = watts_strogatz(N, 4, 0.3, jax.random.key(8))
    m = AxelrodModel(AxelrodConfig(n_agents=N), topology=topo)
    rec = m.create_tasks(jax.random.key(7), 0, 128)
    adj = np.asarray(topo.adjacency())
    src, tgt = np.asarray(rec["src"]), np.asarray(rec["tgt"])
    assert all(adj[a, b] for a, b in zip(src, tgt))


def test_sir_arbitrary_graph_bitexact():
    """SIRS beyond the ring: ER contact graph, derived block adjacency."""
    topo = erdos_renyi(120, 0.05, jax.random.key(4))
    m = SIRModel(SIRConfig(n_agents=120, k=6, subset_size=10, i0=0.3),
                 topology=topo)
    st0 = m.init_state(jax.random.key(2))
    tasks = m.cfg.tasks_per_step() * 4
    cfg = ProtocolConfig(window=40, strict=True)
    w, _ = run_wavefront(m, st0, tasks, seed=3, config=cfg)
    s = run_oracle(m, st0, tasks, seed=3, config=cfg)
    assert bool(jnp.all(w["states"] == s["states"]))
    assert bool(jnp.all(w["new_states"] == s["new_states"]))


def test_sis_epidemic_dynamics():
    """Smoke the dynamics: with beta >> gamma on a connected graph the
    infection persists; with beta = 0 it dies out."""
    topo = ring(N, 4)
    hot = SISModel(topo, SISConfig(beta=0.9, gamma=0.05, i0=0.3))
    w, _ = run_wavefront(hot, hot.init_state(jax.random.key(1)), 3000,
                         seed=0, config=ProtocolConfig(window=64))
    assert int(jnp.sum(w["states"])) > 0
    cold = SISModel(topo, SISConfig(beta=0.0, gamma=0.5, i0=0.3))
    w, _ = run_wavefront(cold, cold.init_state(jax.random.key(1)), 6000,
                         seed=0, config=ProtocolConfig(window=64))
    assert int(jnp.sum(w["states"])) == 0


def test_voter_reaches_consensus_on_small_graph():
    topo = ring(8, 4)
    m = VoterModel(topo, VoterConfig(n_opinions=2))
    st0 = m.init_state(jax.random.key(3))
    w, _ = run_wavefront(m, st0, 4000, seed=1,
                         config=ProtocolConfig(window=64))
    assert len(set(np.asarray(w["opinions"]).tolist())) == 1
