"""Quickstart: run a cultural-dynamics MABS through the adaptive
parallelization protocol, three ways:

  1. sequential oracle          (the chain, executed in order)
  2. SPMD wavefront engine      (the TPU-native adaptation — bit-identical)
  3. protocol DES               (paper-faithful n-worker simulation: T(n))

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import ProtocolConfig, run_oracle, run_wavefront, \
    simulate_protocol
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel


def main():
    model = AxelrodModel(AxelrodConfig(n_agents=500, n_features=20, q=3))
    state0 = model.init_state(jax.random.key(0))
    n_tasks = 2_000
    cfg = ProtocolConfig(window=256, strict=True)

    print("== sequential oracle ==")
    seq = run_oracle(model, state0, n_tasks, seed=42, config=cfg)

    print("== wavefront engine ==")
    wave, stats = run_wavefront(model, state0, n_tasks, seed=42, config=cfg)
    identical = bool(jnp.all(seq["traits"] == wave["traits"]))
    print(f"   bit-identical to sequential: {identical}")
    print(f"   mean wave parallelism: {stats['mean_parallelism']:.1f} "
          f"tasks/wave over {stats['total_waves']} waves")
    assert identical

    print("== protocol simulation (paper §3.3 workflow) ==")
    for n in (1, 2, 4):
        r = simulate_protocol(model.des_model(seed=42), n_tasks,
                              config=ProtocolConfig(n_workers=n))
        print(f"   n={n} workers: T={r.makespan*1e3:.2f} ms, "
              f"per-worker tasks={r.executed_per_worker}")


if __name__ == "__main__":
    main()
