import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Distributed MABS: the sharded execution engine — the paper's protocol
crossing the device boundary.

The ``sharded`` engine shards agent state into contiguous row blocks over
a 1-D ("agents",) mesh and executes each wave under shard_map: wave w
gathers only its *per-wave halo slab* — the read ∪ write rows of the
tasks at level w, split out of the window's halo at schedule time from
the model's ``task_read_agents``/``task_write_agents`` contracts —
instead of re-gathering the whole window halo (let alone all-gathering
the O(N) state); each device runs only the tasks whose write targets
fall in its rows and keeps its local block of the result. Recipes,
conflict matrix, wave levels, and the slab layout stay replicated —
they are window-local. The trajectory is asserted
bit-identical to the single-device wavefront engine and hence to
sequential execution — distribution, like wavefront scheduling itself,
is semantics-free.

Usage:  PYTHONPATH=src python examples/distributed_mabs.py
"""
import jax
import jax.numpy as jnp

from repro.core import ProtocolConfig, run_engine
from repro.mabs.voter import VoterModel
from repro.topology import watts_strogatz


def main():
    print(f"devices: {len(jax.devices())}")
    model = VoterModel(watts_strogatz(1024, 4, 0.1, jax.random.key(0)))
    cfg = ProtocolConfig(window=256, strict=True)
    state0 = model.init_state(jax.random.key(0))

    ref, _ = run_engine(model, state0, 2_000, seed=1, config=cfg,
                        engine="wavefront")
    out, stats = run_engine(model, state0, 2_000, seed=1, config=cfg,
                            engine="sharded")

    same = bool(jnp.all(out["opinions"] == ref["opinions"]))
    print(f"sharded over {stats['n_devices']} devices; "
          f"mean wave parallelism {stats['mean_parallelism']:.1f}")
    print(f"halo exchange: {stats['halo']} "
          f"(per-wave split: {stats['halo_split']}) — per wave "
          f"{stats['per_wave_comm_bytes']} B/device gathered "
          f"(monolithic window halo {stats['window_halo_bytes']} B, "
          f"full state {stats['full_state_bytes']} B)")
    print(f"bit-identical to single-device trajectory: {same}")
    assert same
    print("OK")


if __name__ == "__main__":
    main()
