import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Distributed MABS: the wavefront engine with the simulation state sharded
over a device mesh — the full TPU execution story for the paper's protocol.

Agents (the variable set V) are sharded over the 'data' axis; each wave's
batched execution runs SPMD: gathers of interacting agents' rows become
small collectives, the trait-update scatter stays local to the owning
shard. The trajectory is asserted bit-identical to the single-device run —
distribution, like wavefront scheduling itself, is semantics-free.

Usage:  PYTHONPATH=src python examples/distributed_mabs.py
"""
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ProtocolConfig, run_wavefront
from repro.mabs.axelrod import AxelrodConfig, AxelrodModel


def main():
    n_dev = len(jax.devices())
    mesh = jax.make_mesh((n_dev,), ("data",))
    print(f"devices: {n_dev}")

    model = AxelrodModel(AxelrodConfig(n_agents=1024, n_features=32, q=3))
    cfg = ProtocolConfig(window=256, strict=True)

    # single-device reference
    state0 = model.init_state(jax.random.key(0))
    ref, _ = run_wavefront(model, state0, 2_000, seed=1, config=cfg)

    # sharded run: traits [N, F] split over agents
    sharded0 = jax.device_put(
        state0, {"traits": NamedSharding(mesh, P("data", None))})
    with mesh:
        out, stats = run_wavefront(model, sharded0, 2_000, seed=1,
                                   config=cfg)
    same = bool(jnp.all(out["traits"] == ref["traits"]))
    shards = len(out["traits"].sharding.device_set)
    print(f"state sharded over {shards} devices; "
          f"mean wave parallelism {stats['mean_parallelism']:.1f}")
    print(f"bit-identical to single-device trajectory: {same}")
    assert same
    print("OK")


if __name__ == "__main__":
    main()
