"""End-to-end LM training driver (deliverable b): trains a ~100M-param
smollm-family model for a few hundred steps on the synthetic pipeline with
checkpointing + resume. On this CPU container the default is a reduced
model; pass --full-100m for the real 100M-parameter run (slow on CPU,
intended for a TPU host).

Usage:
  PYTHONPATH=src python examples/train_lm.py              # reduced, 200 steps
  PYTHONPATH=src python examples/train_lm.py --full-100m  # ~100M params
"""
import argparse

import jax

from repro.configs import ARCHS
from repro.models.api import build_model
from repro.train.data import DataConfig, SyntheticLMStream
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import TrainHParams, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = ARCHS["smollm-360m"]
    if args.full_100m:
        # ~101M params: 12L x 640d, GQA 10/5, tied embeddings
        cfg = base.replace(n_layers=12, d_model=640, n_heads=10,
                           n_kv_heads=5, d_ff=1712, head_dim=64,
                           vocab=49152)
        seq, batch = 512, 8
    else:
        cfg = base.reduced()
        seq, batch = 128, 8
    print(f"model: {cfg.name} ({cfg.n_params()/1e6:.1f}M params, "
          f"reduced={not args.full_100m})")

    model = build_model(cfg)
    hp = TrainHParams(peak_lr=3e-3, warmup_steps=20,
                      total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, hp))
    state = init_train_state(model, jax.random.key(0))
    stream = SyntheticLMStream(DataConfig(vocab=cfg.vocab, seq_len=seq,
                                          global_batch=batch))
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=100,
                          log_every=20, ckpt_dir=args.ckpt_dir,
                          metrics_csv=args.ckpt_dir + "/metrics.csv")
    state, report = train_loop(step_fn, state, stream, loop_cfg)
    print(f"ran {report.steps_run} steps "
          f"(resumed from {report.resumed_from}); "
          f"final loss {report.final_metrics['loss']:.4f}")


if __name__ == "__main__":
    main()
