"""Disease-spreading MABS (paper §4.2): SIRS dynamics on a ring graph under
the protocol, with epidemic curves and granularity (s) exploration.

Usage:  PYTHONPATH=src python examples/epidemic.py
"""
import jax
import numpy as np

from repro.core import ProtocolConfig, run_wavefront, simulate_protocol
from repro.core.wavefront import window_schedule_stats
from repro.mabs.sir import SIRConfig, SIRModel


def main():
    cfg = SIRConfig(n_agents=2_000, k=14, subset_size=50,
                    p_si=0.8, p_ir=0.1, p_rs=0.3, i0=0.02)
    model = SIRModel(cfg)
    state = model.init_state(jax.random.key(0))

    print("== epidemic trajectory under the wavefront engine ==")
    pcfg = ProtocolConfig(window=2 * cfg.n_subsets, strict=True)
    for step in range(10):
        state, _ = run_wavefront(model, state, cfg.tasks_per_step(),
                                 seed=step, config=pcfg)
        s = np.asarray(state["states"])
        frac = np.bincount(s, minlength=3) / cfg.n_agents
        bar = "#" * int(frac[1] * 60)
        print(f"  step {step:2d}  S={frac[0]:.2f} I={frac[1]:.2f} "
              f"R={frac[2]:.2f}  {bar}")

    print("== schedule structure at different granularities ==")
    for s_sz in (10, 50, 200):
        m = SIRModel(SIRConfig(n_agents=2_000, k=14, subset_size=s_sz))
        rec = m.create_tasks(jax.random.key(0), 0, 2 * m.cfg.n_subsets)
        import jax.numpy as jnp

        stats = window_schedule_stats(
            m, rec, jnp.ones(2 * m.cfg.n_subsets, bool))
        print(f"  s={s_sz:4d}: {stats['n_tasks']} tasks -> "
              f"{stats['n_waves']} waves "
              f"(parallelism {stats['mean_parallelism']:.1f}, "
              f"conflict density {stats['conflict_density']:.3f})")

    print("== worker scaling (protocol DES, paper Fig. 3 slice) ==")
    m = SIRModel(SIRConfig(n_agents=2_000, k=14, subset_size=100))
    tasks = m.cfg.tasks_per_step() * 5
    for n in (1, 2, 4, 5):
        r = simulate_protocol(m.des_model(), tasks,
                              config=ProtocolConfig(n_workers=n))
        print(f"  n={n}: T={r.makespan*1e3:.2f} ms")


if __name__ == "__main__":
    main()
