"""LLM serving demo: the paper's protocol as a continuous-batching
scheduler (DESIGN.md §4). Requests arrive mid-flight; chunked prefill
keeps long prompts from blocking decode waves.

Usage:  PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs import ARCHS
from repro.models.api import build_model
from repro.serving.engine import Request, ServingEngine


def main():
    cfg = ARCHS["smollm-360m"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = ServingEngine(model, params, n_slots=3, max_len=96,
                           prefill_chunk=8)

    rng = np.random.RandomState(0)
    for i, plen in enumerate([6, 40, 9]):       # one long "straggler"
        engine.submit(Request(
            rid=i, prompt=rng.randint(0, cfg.vocab, plen).astype(np.int32),
            max_new_tokens=8))

    # run a few protocol iterations, then a request arrives mid-flight
    for _ in range(4):
        engine.step()
    print("mid-flight arrival of request 3 ...")
    engine.submit(Request(
        rid=3, prompt=rng.randint(0, cfg.vocab, 5).astype(np.int32),
        max_new_tokens=8))
    engine.run()

    print(f"protocol iterations: {engine.iterations}, "
          f"wave sizes: {engine.wave_sizes}")
    for r in sorted(engine.finished, key=lambda r: r.rid):
        print(f"  req {r.rid} (prompt {len(r.prompt):2d} tok) "
              f"-> {r.out_tokens}")
    order = [r.rid for r in engine.finished]
    print(f"completion order: {order} "
          f"(the 40-token straggler did not block the short requests)")


if __name__ == "__main__":
    main()
